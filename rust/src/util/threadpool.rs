//! Minimal scoped thread pool for per-layer optimizer dispatch.
//!
//! [`ThreadPool::run_all_scoped`] is the hot-path API: `LowRank::step`
//! fans per-slot updates (which borrow the optimizer's state and the
//! parameter buffers) out to the workers and blocks until all complete,
//! so jobs may safely capture non-`'static` borrows. Worker panics are
//! caught per job and re-raised on the caller thread after the batch
//! drains, so a poisoned slot can't wedge or kill the pool.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("coap-worker-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Run `jobs` to completion, blocking the caller until all finish.
    pub fn run_all<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        self.run_all_scoped(jobs)
    }

    /// Like [`Self::run_all`], but jobs may capture non-`'static` borrows
    /// (e.g. `&mut` slices of the caller's buffers). Results come back in
    /// job-index order regardless of completion order; if any job
    /// panicked, the first panic (by index) is re-raised here after every
    /// job of the batch has finished.
    ///
    /// Generic over the closure type so callers hand over plain (unboxed)
    /// closures: each job is boxed exactly once here, by the wrapper that
    /// pairs it with its result slot — the old `Vec<Box<dyn FnOnce>>`
    /// signature forced a second box per job on the hot step path.
    pub fn run_all_scoped<'scope, T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'scope,
        F: FnOnce() -> T + Send + 'scope,
    {
        if jobs.is_empty() {
            // Nothing to fan out: return before creating the result
            // channel or touching the job queue.
            return Vec::new();
        }
        let n = jobs.len();
        // Pre-sized rendezvous buffer: every send finds a free slot, so
        // workers never block on the result channel.
        let (tx, rx) = mpsc::sync_channel::<(usize, thread::Result<T>)>(n);
        for (i, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(job));
                let _ = tx.send((i, out));
            });
            // SAFETY: this function blocks below until all `n` results
            // (including panics) have been received, so no job — and no
            // borrow it captures — outlives this call. The transmute only
            // erases the `'scope` lifetime; layout is identical.
            let wrapped: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(wrapped)
            };
            self.tx.as_ref().unwrap().send(wrapped).expect("pool closed");
        }
        drop(tx);
        let mut slots: Vec<Option<thread::Result<T>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, out) = rx.recv().expect("worker died");
            slots[i] = Some(out);
        }
        let mut out = Vec::with_capacity(n);
        for s in slots {
            match s.expect("missing job result") {
                Ok(v) => out.push(v),
                Err(payload) => resume_unwind(payload),
            }
        }
        out
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs_in_order_of_index() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..32usize).map(|i| Box::new(move || i * 2) as _).collect();
        let out = pool.run_all(jobs);
        assert_eq!(out, (0..32usize).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn submit_executes() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop waits for workers to drain the queue.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn scoped_jobs_mutate_borrowed_buffers() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0usize; 16];
        // Unboxed closures straight into the pool — the generic
        // signature boxes each exactly once internally.
        let jobs: Vec<_> = data
            .chunks_mut(4)
            .enumerate()
            .map(|(i, chunk)| {
                move || {
                    for (j, c) in chunk.iter_mut().enumerate() {
                        *c = i * 10 + j;
                    }
                }
            })
            .collect();
        pool.run_all_scoped(jobs);
        assert_eq!(data[5], 11);
        assert_eq!(data[15], 33);
    }

    #[test]
    fn empty_batch_returns_without_touching_the_pool() {
        let pool = ThreadPool::new(2);
        let out = pool.run_all_scoped(Vec::<fn() -> usize>::new());
        assert!(out.is_empty());
        // The pool is still fully usable afterwards.
        assert_eq!(pool.run_all_scoped(vec![|| 5usize]), vec![5]);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates_to_caller() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            vec![Box::new(|| 1), Box::new(|| panic!("boom")), Box::new(|| 3)];
        pool.run_all(jobs);
    }

    #[test]
    fn pool_survives_a_panicked_batch() {
        let pool = ThreadPool::new(2);
        let bad: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![Box::new(|| panic!("x"))];
        assert!(catch_unwind(AssertUnwindSafe(|| pool.run_all(bad))).is_err());
        let good: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![Box::new(|| 7)];
        assert_eq!(pool.run_all(good), vec![7]);
    }
}
