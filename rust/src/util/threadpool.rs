//! Minimal scoped thread pool for per-layer optimizer dispatch.
//!
//! The coordinator fans per-layer state updates out to workers while the
//! next batch's gradients are computed. On this single-core testbed the
//! pool mostly provides *overlap* (XLA releases the GIL-free CPU between
//! executions), but the code is written for multi-core boxes.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("coap-worker-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Run `jobs` to completion, blocking the caller until all finish.
    pub fn run_all<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (tx, rx) = mpsc::channel();
        for (i, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            self.submit(move || {
                let out = job();
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, out) = rx.recv().expect("worker died");
            slots[i] = Some(out);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs_in_order_of_index() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..32usize).map(|i| Box::new(move || i * 2) as _).collect();
        let out = pool.run_all(jobs);
        assert_eq!(out, (0..32usize).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn submit_executes() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop waits for workers to drain the queue.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
