//! Acceptance suite for the sharded sweep orchestrator: `Sweep::run`
//! with any worker count must return `TrainReport` rows **bit-identical**
//! to serial execution, in spec order, and the merged event stream must
//! pair every run's `RunStarted`/`RunFinished` correctly around its
//! steps — the determinism contract PR 1–3 established for `--threads`,
//! lifted to whole runs.

use coap::config::{OptKind, TrainConfig};
use coap::coordinator::{CollectSink, RunSpec, Sweep, TrainEvent, TrainReport, Trainer};
use coap::runtime::{Backend, NativeBackend};
use std::sync::Arc;

fn backend() -> Arc<dyn Backend> {
    Arc::new(NativeBackend::new())
}

/// A spread of models × optimizer families over the `*_micro` census —
/// small enough that the 1/2/8-worker matrix stays fast, wide enough to
/// cover matrix, conv and vector slots plus eval + CEU recording.
fn micro_specs(steps: usize) -> Vec<RunSpec> {
    let mk = |label: &str, model: &str, opt: OptKind| {
        let mut c = TrainConfig::default();
        c.model = model.into();
        c.optimizer = opt;
        c.steps = steps;
        c.lr = 3e-3;
        c.t_update = 3;
        c.lambda = 2;
        c.eval_every = steps;
        c.eval_batches = 1;
        c.log_every = 0;
        c.track_ceu = true;
        RunSpec::new(label, c)
    };
    vec![
        mk("coap/lm", "lm_micro", OptKind::Coap),
        mk("galore/vit", "vit_micro", OptKind::Galore),
        mk("adamw/lm", "lm_micro", OptKind::AdamW),
        mk("flora/cnn", "cnn_micro", OptKind::Flora),
        mk("coap-af/ctrl", "ctrl_micro", OptKind::CoapAdafactor),
    ]
}

/// Everything deterministic in a report, with floats as raw bits.
type RowKey = (String, Vec<(usize, u64)>, Vec<(usize, u64)>, Vec<u64>, usize, usize);

fn row_key(r: &TrainReport) -> RowKey {
    (
        r.label.clone(),
        r.train_losses.iter().map(|(s, l)| (*s, l.to_bits())).collect(),
        r.ceu_curve.iter().map(|(s, c)| (*s, c.to_bits())).collect(),
        r.evals.iter().map(|e| e.loss.to_bits()).collect(),
        r.optimizer_bytes,
        r.param_bytes,
    )
}

/// Sharded execution (workers = 1, 2, 8) must be bit-identical, row for
/// row and in spec order, to running each spec serially by hand.
#[test]
fn sharded_sweep_matches_serial_bitwise() {
    let rt = backend();
    let serial: Vec<RowKey> = micro_specs(6)
        .into_iter()
        .map(|spec| {
            let mut tr = Trainer::builder(spec.cfg)
                .backend(Arc::clone(&rt))
                .label(&spec.label)
                .quiet()
                .build()
                .unwrap();
            row_key(&tr.run().unwrap())
        })
        .collect();
    for workers in [1usize, 2, 8] {
        let reports = Sweep::new(micro_specs(6))
            .workers(workers)
            .run(&rt)
            .unwrap();
        let sharded: Vec<RowKey> = reports.iter().map(row_key).collect();
        assert_eq!(serial, sharded, "sweep drifted from serial at workers={workers}");
    }
}

/// The merged event stream: every run gets exactly one
/// `RunStarted`/`RunFinished` pair, its steps land between them in
/// ascending order, and reports come back in spec order regardless of
/// which worker ran what.
#[test]
fn event_stream_pairs_and_orders_each_run() {
    let rt = backend();
    let steps = 4usize;
    let specs = micro_specs(steps);
    let labels: Vec<String> = specs.iter().map(|s| s.label.clone()).collect();
    let n = specs.len();
    let sink = Arc::new(CollectSink::default());
    let reports = Sweep::new(specs)
        .workers(2)
        .events(sink.clone())
        .run(&rt)
        .unwrap();

    assert_eq!(
        reports.iter().map(|r| r.label.clone()).collect::<Vec<_>>(),
        labels,
        "reports not in spec order"
    );

    let events = sink.take();
    for run in 0..n {
        let mine: Vec<&TrainEvent> = events.iter().filter(|e| e.run() == run).collect();
        let started: Vec<usize> = mine
            .iter()
            .enumerate()
            .filter(|(_, e)| matches!(e, TrainEvent::RunStarted { .. }))
            .map(|(i, _)| i)
            .collect();
        let finished: Vec<usize> = mine
            .iter()
            .enumerate()
            .filter(|(_, e)| matches!(e, TrainEvent::RunFinished { .. }))
            .map(|(i, _)| i)
            .collect();
        assert_eq!(started, vec![0], "run {run}: RunStarted not first/unique");
        assert_eq!(
            finished,
            vec![mine.len() - 1],
            "run {run}: RunFinished not last/unique"
        );
        let step_nos: Vec<usize> = mine
            .iter()
            .filter_map(|e| match e {
                TrainEvent::Step { step, .. } => Some(*step),
                _ => None,
            })
            .collect();
        assert_eq!(step_nos, (1..=steps).collect::<Vec<_>>(), "run {run}: step order");
        for e in &mine {
            assert_eq!(e.label(), labels[run], "run {run}: label mismatch");
        }
    }
}

/// More workers than specs, and an empty sweep, are fine.
#[test]
fn degenerate_worker_counts() {
    let rt = backend();
    let reports = Sweep::new(micro_specs(2).into_iter().take(2).collect())
        .workers(16)
        .run(&rt)
        .unwrap();
    assert_eq!(reports.len(), 2);
    let empty = Sweep::new(Vec::new()).workers(4).run(&rt).unwrap();
    assert!(empty.is_empty());
}

/// A failing row (unknown model) surfaces as an error naming the row,
/// after the other rows drain — no panic, no hang.
#[test]
fn row_failure_is_reported_with_spec_context() {
    let rt = backend();
    let mut specs = micro_specs(2);
    let mut bad = TrainConfig::default();
    bad.model = "no_such_model".into();
    bad.steps = 2;
    specs.insert(1, RunSpec::new("broken-row", bad));
    let err = Sweep::new(specs).workers(2).run(&rt).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("broken-row"), "error lacks spec context: {msg}");
}

/// Sweep-level sharding composes with in-run `--threads` parallelism:
/// 4 sweep workers over a pooled-GEMM backend, each trainer fanning its
/// optimizer slots across 4 threads, stays bit-identical to the fully
/// serial configuration (serial backend, 1-thread trainers, 1 worker).
#[test]
fn sharding_composes_with_per_run_threads() {
    let with_threads = |threads: usize| -> Vec<RunSpec> {
        micro_specs(4)
            .into_iter()
            .map(|mut s| {
                s.cfg.threads = threads;
                s
            })
            .collect()
    };
    let serial_rt: Arc<dyn Backend> = Arc::new(NativeBackend::new());
    let serial = Sweep::new(with_threads(1)).workers(1).run(&serial_rt).unwrap();
    // cfg.threads drives each trainer's per-slot optimizer pool; the
    // backend's GEMM pool must be pooled explicitly (the builder only
    // opens its own backend when none is supplied).
    let pooled_rt: Arc<dyn Backend> = Arc::new(NativeBackend::with_threads(4));
    let sharded = Sweep::new(with_threads(4)).workers(4).run(&pooled_rt).unwrap();
    let a: Vec<RowKey> = serial.iter().map(row_key).collect();
    let b: Vec<RowKey> = sharded.iter().map(row_key).collect();
    assert_eq!(a, b);
}
