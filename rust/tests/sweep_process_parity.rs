//! Acceptance suite for process-mode sweep execution: for every named
//! micro sweep, `ExecMode::Process` (subprocess `coap worker` children
//! over the `coordinator::wire`) must return `TrainReport` rows
//! **bit-identical** to serial and to thread-sharded execution, with
//! identical ordered per-run event sequences — the PR-4 thread-sharding
//! determinism contract lifted across a process boundary. Plus the
//! failure surface: a child that dies (clean error frame, nonzero exit,
//! or a truncated stream) becomes the failed spec's error by index,
//! after in-flight rows drain.
//!
//! The worker binary is the real `coap` CLI (CARGO_BIN_EXE_coap), so
//! this suite also pins the hidden `coap worker` subcommand end to end.

use coap::config::{OptKind, TrainConfig};
use coap::coordinator::wire::{self, Frame};
use coap::coordinator::{CollectSink, ExecMode, RunSpec, Sweep, TrainEvent, TrainReport};
use coap::runtime::{Backend, NativeBackend};
use std::io::{BufRead, BufReader, Write};
use std::process::{Command, Stdio};
use std::sync::Arc;

/// The `coap` binary cargo built for this test run.
const WORKER_EXE: &str = env!("CARGO_BIN_EXE_coap");

fn backend() -> Arc<dyn Backend> {
    Arc::new(NativeBackend::new())
}

fn mk(label: &str, model: &str, opt: OptKind, steps: usize) -> RunSpec {
    let mut c = TrainConfig::default();
    c.model = model.into();
    c.optimizer = opt;
    c.steps = steps;
    c.lr = 3e-3;
    c.t_update = 3;
    c.lambda = 2;
    c.eval_every = steps;
    c.eval_batches = 1;
    c.log_every = 0;
    c.track_ceu = true;
    RunSpec::new(label, c)
}

/// The named micro sweeps: a spread of models × optimizer families over
/// the `*_micro` census, grouped the way the mode matrix iterates them.
/// Covers matrix, conv and vector slots, eval + CEU recording, and both
/// moment bases.
fn named_micro_sweeps(steps: usize) -> Vec<(&'static str, Vec<RunSpec>)> {
    vec![
        (
            "lm-micro",
            vec![
                mk("coap/lm", "lm_micro", OptKind::Coap, steps),
                mk("adamw/lm", "lm_micro", OptKind::AdamW, steps),
            ],
        ),
        (
            "vision-micro",
            vec![
                mk("galore/vit", "vit_micro", OptKind::Galore, steps),
                mk("flora/cnn", "cnn_micro", OptKind::Flora, steps),
            ],
        ),
        (
            "ctrl-micro",
            vec![mk("coap-af/ctrl", "ctrl_micro", OptKind::CoapAdafactor, steps)],
        ),
    ]
}

fn micro_sweep(name: &str, steps: usize) -> Vec<RunSpec> {
    named_micro_sweeps(steps)
        .into_iter()
        .find(|(n, _)| *n == name)
        .expect("known micro sweep")
        .1
}

/// Everything deterministic in a report, with floats as raw bits.
type RowKey = (String, Vec<(usize, u64)>, Vec<(usize, u64)>, Vec<u64>, usize, usize);

fn row_key(r: &TrainReport) -> RowKey {
    (
        r.label.clone(),
        r.train_losses.iter().map(|(s, l)| (*s, l.to_bits())).collect(),
        r.ceu_curve.iter().map(|(s, c)| (*s, c.to_bits())).collect(),
        r.evals.iter().map(|e| e.loss.to_bits()).collect(),
        r.optimizer_bytes,
        r.param_bytes,
    )
}

/// Everything deterministic in an event (wall-clock ms fields excluded),
/// with floats as raw bits.
fn event_key(ev: &TrainEvent) -> String {
    match ev {
        TrainEvent::RunStarted { run, label, model, steps } => {
            format!("started {run} '{label}' {model} {steps}")
        }
        TrainEvent::Step { run, label, step, loss, ema, .. } => {
            format!("step {run} '{label}' {step} {:x} {:x}", loss.to_bits(), ema.to_bits())
        }
        TrainEvent::ProjRefresh { run, label, step, .. } => {
            format!("proj {run} '{label}' {step}")
        }
        TrainEvent::Eval { run, label, eval } => format!(
            "eval {run} '{label}' {} {:x} {:x} {:?} {:?}",
            eval.step,
            eval.loss.to_bits(),
            eval.ppl.to_bits(),
            eval.accuracy.map(f64::to_bits),
            eval.aux.map(f64::to_bits),
        ),
        TrainEvent::RunFinished { run, label, steps, final_train_loss, .. } => {
            format!("finished {run} '{label}' {steps} {:x}", final_train_loss.to_bits())
        }
        TrainEvent::RunFailed { run, label, step, .. } => {
            format!("failed {run} '{label}' {step}")
        }
        // Remote dispatch bookkeeping: never emitted by thread/process
        // pools, and excluded from cross-mode parity by construction
        // (which peer ran a row is not part of the row's result).
        TrainEvent::RowDispatched { run, label, peer, attempt } => {
            format!("dispatched {run} '{label}' {peer} {attempt}")
        }
        TrainEvent::RowRequeued { run, label, peer, attempt, .. } => {
            format!("requeued {run} '{label}' {peer} {attempt}")
        }
    }
}

fn run_mode(name: &str, steps: usize, mode: ExecMode) -> (Vec<TrainReport>, Vec<TrainEvent>) {
    let rt = backend();
    let sink = Arc::new(CollectSink::default());
    let reports = Sweep::new(micro_sweep(name, steps))
        .mode(mode)
        .worker_exe(WORKER_EXE)
        .events(sink.clone())
        .run(&rt)
        .unwrap_or_else(|e| panic!("{name} under {mode:?}: {e:#}"));
    (reports, sink.take())
}

/// The tentpole contract: for every named micro sweep, process-sharded
/// execution returns reports bit-identical to serial and to
/// thread-sharded execution, in spec order, and each run's ordered
/// event sequence is identical (timing fields aside) across the modes.
#[test]
fn process_sweep_matches_serial_and_threads_bitwise() {
    let steps = 5;
    for (name, specs) in named_micro_sweeps(steps) {
        let n = specs.len();
        let (serial_reports, serial_events) =
            run_mode(name, steps, ExecMode::Threads { workers: 1 });
        assert_eq!(serial_reports.len(), n, "{name}");
        let serial_keys: Vec<RowKey> = serial_reports.iter().map(row_key).collect();
        let serial_seq: Vec<Vec<String>> = (0..n)
            .map(|run| {
                serial_events
                    .iter()
                    .filter(|e| e.run() == run)
                    .map(event_key)
                    .collect()
            })
            .collect();
        // Sanity: the serial per-run sequence is nonempty and bracketed.
        for (run, seq) in serial_seq.iter().enumerate() {
            assert!(seq.len() >= 2, "{name} run {run}: {seq:?}");
            assert!(seq[0].starts_with("started"), "{name} run {run}");
            assert!(seq[seq.len() - 1].starts_with("finished"), "{name} run {run}");
        }

        for mode in [
            ExecMode::Threads { workers: 2 },
            ExecMode::Threads { workers: 8 },
            ExecMode::Process { max_procs: 2 },
        ] {
            let (reports, events) = run_mode(name, steps, mode);
            let keys: Vec<RowKey> = reports.iter().map(row_key).collect();
            assert_eq!(serial_keys, keys, "{name}: reports drifted under {mode:?}");
            for run in 0..n {
                let seq: Vec<String> =
                    events.iter().filter(|e| e.run() == run).map(event_key).collect();
                assert_eq!(
                    serial_seq[run], seq,
                    "{name} run {run}: event sequence drifted under {mode:?}"
                );
            }
        }
    }
}

/// A failing child (unknown model -> clean error frame + nonzero exit)
/// surfaces as the failed spec's error by index and label, while the
/// in-flight lower-index row drains to completion.
#[test]
fn child_failure_is_spec_indexed_and_inflight_rows_drain() {
    let rt = backend();
    let mut specs = micro_sweep("lm-micro", 3);
    let mut bad = TrainConfig::default();
    bad.model = "no_such_model".into();
    bad.steps = 3;
    specs.insert(1, RunSpec::new("broken-row", bad));
    let sink = Arc::new(CollectSink::default());
    let err = Sweep::new(specs)
        .mode(ExecMode::Process { max_procs: 2 })
        .worker_exe(WORKER_EXE)
        .events(sink.clone())
        .run(&rt)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("sweep row 1"), "error lacks spec index: {msg}");
    assert!(msg.contains("broken-row"), "error lacks spec label: {msg}");

    // Row 0 was pulled before row 1 (the cursor is monotonic), so it
    // was in flight when row 1 failed — it must drain: exactly one
    // RunStarted and one terminal RunFinished, all its steps between.
    let events = sink.take();
    let row0: Vec<&TrainEvent> = events.iter().filter(|e| e.run() == 0).collect();
    assert!(
        matches!(row0.first(), Some(TrainEvent::RunStarted { .. })),
        "row 0 did not start: {row0:?}"
    );
    assert!(
        matches!(row0.last(), Some(TrainEvent::RunFinished { .. })),
        "row 0 did not drain to completion: {row0:?}"
    );
    // Every started run reached exactly one terminal event (drained or
    // failed) — nothing was abandoned mid-flight.
    let runs: Vec<usize> = events
        .iter()
        .filter(|e| matches!(e, TrainEvent::RunStarted { .. }))
        .map(TrainEvent::run)
        .collect();
    for run in runs {
        let terminals = events
            .iter()
            .filter(|e| {
                e.run() == run
                    && matches!(
                        e,
                        TrainEvent::RunFinished { .. } | TrainEvent::RunFailed { .. }
                    )
            })
            .count();
        assert_eq!(terminals, 1, "run {run} has {terminals} terminal events");
    }
}

/// A child killed before it produces its report frame — simulated by
/// worker binaries that exit without speaking the wire — surfaces as
/// the failed spec's error, not a hang, panic or silent success.
#[test]
fn killed_child_stream_is_a_spec_indexed_error() {
    // Exits 0 without a report: the truncated-stream path.
    let rt = backend();
    let err = Sweep::new(micro_sweep("lm-micro", 2))
        .mode(ExecMode::Process { max_procs: 1 })
        .worker_exe("true")
        .run(&rt)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("sweep row 0"), "{msg}");
    assert!(msg.contains("coap/lm"), "{msg}");

    // Exits nonzero without a report: the exit-status path (what a
    // SIGKILL'd worker reports through wait()).
    let err = Sweep::new(micro_sweep("lm-micro", 2))
        .mode(ExecMode::Process { max_procs: 1 })
        .worker_exe("false")
        .run(&rt)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("sweep row 0"), "{msg}");

    // A worker binary that doesn't exist: the spawn path.
    let err = Sweep::new(micro_sweep("lm-micro", 2))
        .mode(ExecMode::Process { max_procs: 1 })
        .worker_exe("/nonexistent/coap-worker-binary")
        .run(&rt)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("sweep row 0") && msg.contains("spawning worker"), "{msg}");
}

/// Error precedence: a worker that emits a clean error frame and THEN
/// exits nonzero must surface the error frame's message — the exit
/// status is the less specific verdict and must not mask it. Pinned
/// with a fake worker script so the precedence can't silently invert.
#[cfg(unix)]
#[test]
fn error_frame_beats_nonzero_exit_and_keeps_spec_index() {
    use std::os::unix::fs::PermissionsExt;
    let dir = std::env::temp_dir().join(format!("coap-wire-prec-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let frame = wire::encode_error("deterministic kaboom at step 2");
    assert!(!frame.contains('\''), "frame must be single-quote-safe for sh: {frame}");
    let script = dir.join("lying-worker.sh");
    std::fs::write(&script, format!("#!/bin/sh\necho '{frame}'\nexit 3\n")).unwrap();
    let mut perm = std::fs::metadata(&script).unwrap().permissions();
    perm.set_mode(0o755);
    std::fs::set_permissions(&script, perm).unwrap();

    let rt = backend();
    let err = Sweep::new(micro_sweep("lm-micro", 2))
        .mode(ExecMode::Process { max_procs: 1 })
        .worker_exe(&script)
        .run(&rt)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("worker failed: deterministic kaboom at step 2"),
        "error frame message lost: {msg}"
    );
    assert!(!msg.contains("exited with"), "exit status masked the error frame: {msg}");
    assert!(msg.contains("sweep row 0") && msg.contains("coap/lm"), "{msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Drive `coap worker` by hand: every stdout line must be a
/// schema-checked wire frame, events first (bracketed Started ->
/// Finished), the report last, exit status zero.
#[test]
fn worker_stdout_is_schema_checked_jsonl() {
    let spec = mk("coap/lm", "lm_micro", OptKind::Coap, 3);
    let mut child = Command::new(WORKER_EXE)
        .arg("worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn coap worker");
    {
        let mut si = child.stdin.take().unwrap();
        writeln!(si, "{}", wire::encode_spec(4, &spec)).unwrap();
    }
    let mut frames = Vec::new();
    for line in BufReader::new(child.stdout.take().unwrap()).lines() {
        let line = line.unwrap();
        if line.is_empty() {
            continue;
        }
        frames.push(
            wire::decode_frame(&line)
                .unwrap_or_else(|e| panic!("unschematic worker line: {line}: {e:#}")),
        );
    }
    assert!(child.wait().unwrap().success());
    assert!(frames.len() >= 3, "expected started/finished/report at least");
    match &frames[0] {
        Frame::Event(TrainEvent::RunStarted { run, label, .. }) => {
            assert_eq!(*run, 4, "spec index must ride every event");
            assert_eq!(&**label, "coap/lm");
        }
        _ => panic!("first frame is not RunStarted"),
    }
    match &frames[frames.len() - 2] {
        Frame::Event(TrainEvent::RunFinished { .. }) => {}
        _ => panic!("penultimate frame is not RunFinished"),
    }
    match frames.last().unwrap() {
        Frame::Report(rep) => assert_eq!(rep.label, "coap/lm"),
        _ => panic!("last frame is not the report"),
    }
}

/// Garbage or version-skewed stdin makes the worker exit nonzero
/// without emitting a report frame.
#[test]
fn worker_rejects_garbage_and_version_skew() {
    for bad in ["definitely not a frame", "{\"v\":999,\"frame\":\"spec\",\"spec\":{}}"] {
        let mut child = Command::new(WORKER_EXE)
            .arg("worker")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn coap worker");
        {
            let mut si = child.stdin.take().unwrap();
            writeln!(si, "{bad}").unwrap();
        }
        let mut out = String::new();
        use std::io::Read;
        child.stdout.take().unwrap().read_to_string(&mut out).unwrap();
        let status = child.wait().unwrap();
        assert!(!status.success(), "worker accepted: {bad}");
        assert!(
            !out.contains("\"frame\":\"report\""),
            "worker reported on garbage: {out}"
        );
    }
}
