//! Acceptance suite for remote sweep dispatch (`coordinator::remote`):
//! a sweep fanned out over loopback `coap serve-worker` peers must
//! return `TrainReport` rows **bit-identical** to serial execution, in
//! spec order, with identical per-run event sequences — including when
//! a peer is killed mid-row and its in-flight row is re-dispatched to a
//! healthy peer. Plus the refusal surface: version-skewed peers are
//! rejected at the hello, hung peers time out and lose the row to a
//! healthy peer, row-level errors keep first-error-by-spec-index
//! semantics and are never retried, and rows whose backend no peer
//! advertises fail cleanly instead of deadlocking.
//!
//! The peers are the real `coap` CLI (CARGO_BIN_EXE_coap) speaking the
//! real TCP framing, so this suite pins `coap serve-worker` end to end.

use coap::config::{BackendKind, OptKind, TrainConfig};
use coap::coordinator::remote::{self, RemoteOpts};
use coap::coordinator::wire::{self, WireHello};
use coap::coordinator::{CollectSink, ExecMode, RunSpec, Sweep, TrainEvent, TrainReport};
use coap::runtime::{Backend, NativeBackend};
use std::net::TcpListener;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

/// The `coap` binary cargo built for this test run.
const WORKER_EXE: &str = env!("CARGO_BIN_EXE_coap");

fn backend() -> Arc<dyn Backend> {
    Arc::new(NativeBackend::new())
}

fn mk(label: &str, model: &str, opt: OptKind, steps: usize) -> RunSpec {
    let mut c = TrainConfig::default();
    c.model = model.into();
    c.optimizer = opt;
    c.steps = steps;
    c.lr = 3e-3;
    c.t_update = 3;
    c.lambda = 2;
    c.eval_every = steps;
    c.eval_batches = 1;
    c.log_every = 0;
    c.track_ceu = true;
    RunSpec::new(label, c)
}

/// Four micro rows spanning matrix, vector and conv slots — enough for
/// both peers to see work and for a killed peer to leave rows behind.
fn micro_specs(steps: usize) -> Vec<RunSpec> {
    vec![
        mk("coap/lm", "lm_micro", OptKind::Coap, steps),
        mk("adamw/lm", "lm_micro", OptKind::AdamW, steps),
        mk("galore/vit", "vit_micro", OptKind::Galore, steps),
        mk("flora/cnn", "cnn_micro", OptKind::Flora, steps),
    ]
}

/// Everything deterministic in a report, with floats as raw bits
/// (wall-clock fields excluded — they are measured, not computed).
type RowKey = (String, Vec<(usize, u64)>, Vec<(usize, u64)>, Vec<u64>, usize, usize);

fn row_key(r: &TrainReport) -> RowKey {
    (
        r.label.clone(),
        r.train_losses.iter().map(|(s, l)| (*s, l.to_bits())).collect(),
        r.ceu_curve.iter().map(|(s, c)| (*s, c.to_bits())).collect(),
        r.evals.iter().map(|e| e.loss.to_bits()).collect(),
        r.optimizer_bytes,
        r.param_bytes,
    )
}

/// Everything deterministic in an event (timing fields excluded).
fn event_key(ev: &TrainEvent) -> String {
    match ev {
        TrainEvent::RunStarted { run, label, model, steps } => {
            format!("started {run} '{label}' {model} {steps}")
        }
        TrainEvent::Step { run, label, step, loss, ema, .. } => {
            format!("step {run} '{label}' {step} {:x} {:x}", loss.to_bits(), ema.to_bits())
        }
        TrainEvent::ProjRefresh { run, label, step, .. } => {
            format!("proj {run} '{label}' {step}")
        }
        TrainEvent::Eval { run, label, eval } => {
            format!("eval {run} '{label}' {} {:x}", eval.step, eval.loss.to_bits())
        }
        TrainEvent::RunFinished { run, label, steps, final_train_loss, .. } => {
            format!("finished {run} '{label}' {steps} {:x}", final_train_loss.to_bits())
        }
        TrainEvent::RunFailed { run, label, step, .. } => {
            format!("failed {run} '{label}' {step}")
        }
        TrainEvent::RowDispatched { run, label, peer, attempt } => {
            format!("dispatched {run} '{label}' {peer} {attempt}")
        }
        TrainEvent::RowRequeued { run, label, peer, attempt, .. } => {
            format!("requeued {run} '{label}' {peer} {attempt}")
        }
    }
}

fn is_dispatch(ev: &TrainEvent) -> bool {
    matches!(ev, TrainEvent::RowDispatched { .. } | TrainEvent::RowRequeued { .. })
}

/// Retry knobs tuned so fault-injection tests run in seconds.
fn fast_opts() -> RemoteOpts {
    RemoteOpts {
        backoff_base: Duration::from_millis(20),
        connect_timeout: Duration::from_secs(2),
        ..RemoteOpts::default()
    }
}

fn run_mode(
    specs: Vec<RunSpec>,
    mode: ExecMode,
    opts: RemoteOpts,
) -> (Vec<TrainReport>, Vec<TrainEvent>) {
    let rt = backend();
    let sink = Arc::new(CollectSink::default());
    let reports = Sweep::new(specs)
        .mode(mode.clone())
        .worker_exe(WORKER_EXE)
        .remote_opts(opts)
        .events(sink.clone())
        .run(&rt)
        .unwrap_or_else(|e| panic!("sweep under {mode:?}: {e:#}"));
    (reports, sink.take())
}

/// Assert `reports`/`events` from a remote run match the serial
/// baseline: bit-identical spec-ordered rows, and per-run event
/// sequences identical once the dispatch bookkeeping (which peer ran a
/// row — not part of the row's result) is filtered out.
fn assert_matches_serial(
    n: usize,
    serial: &(Vec<TrainReport>, Vec<TrainEvent>),
    remote: &(Vec<TrainReport>, Vec<TrainEvent>),
    what: &str,
) {
    assert_eq!(remote.0.len(), n, "{what}: row count");
    let serial_keys: Vec<RowKey> = serial.0.iter().map(row_key).collect();
    let remote_keys: Vec<RowKey> = remote.0.iter().map(row_key).collect();
    assert_eq!(serial_keys, remote_keys, "{what}: reports drifted from serial");
    for run in 0..n {
        let want: Vec<String> = serial
            .1
            .iter()
            .filter(|e| e.run() == run && !is_dispatch(e))
            .map(event_key)
            .collect();
        let got: Vec<String> = remote
            .1
            .iter()
            .filter(|e| e.run() == run && !is_dispatch(e))
            .map(event_key)
            .collect();
        assert_eq!(want, got, "{what}: run {run} event sequence drifted from serial");
    }
}

/// A minimal in-test TCP peer: accepts connections forever and hands
/// each to `serve`. The thread leaks (blocked in accept) when the test
/// ends — the process exit reaps it.
fn fake_peer(serve: impl Fn(std::net::TcpStream) + Send + 'static) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind fake peer");
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            match conn {
                Ok(s) => serve(s),
                Err(_) => break,
            }
        }
    });
    addr
}

/// The tentpole contract: a sweep across two loopback `serve-worker`
/// peers is bit-identical to serial execution.
#[test]
fn tcp_remote_sweep_matches_serial_bitwise() {
    let steps = 5;
    let n = micro_specs(steps).len();
    let serial = run_mode(
        micro_specs(steps),
        ExecMode::Threads { workers: 1 },
        RemoteOpts::default(),
    );

    let exe = Path::new(WORKER_EXE);
    let a = remote::spawn_serve_worker(exe, &[]).expect("spawn peer a");
    let b = remote::spawn_serve_worker(exe, &[]).expect("spawn peer b");
    let remote_run = run_mode(
        micro_specs(steps),
        ExecMode::Remote { peers: vec![a.addr.clone(), b.addr.clone()] },
        fast_opts(),
    );
    assert_matches_serial(n, &serial, &remote_run, "tcp x2");

    // Every row was dispatched somewhere, and the dispatch events name
    // real pool members.
    let peers = [a.addr.clone(), b.addr.clone()];
    let mut dispatched = vec![false; n];
    for ev in &remote_run.1 {
        if let TrainEvent::RowDispatched { run, peer, .. } = ev {
            dispatched[*run] = true;
            assert!(peers.contains(peer), "dispatch names unknown peer {peer}");
        }
    }
    assert!(dispatched.iter().all(|&d| d), "undispatched rows: {dispatched:?}");
}

/// `proc` peers (the PR-5 subprocess transport behind the same
/// scheduler) produce the same bits as serial too — the two in-tree
/// transports are interchangeable.
#[test]
fn proc_peers_match_serial_bitwise() {
    let steps = 4;
    let n = micro_specs(steps).len();
    let serial = run_mode(
        micro_specs(steps),
        ExecMode::Threads { workers: 1 },
        RemoteOpts::default(),
    );
    let remote_run = run_mode(
        micro_specs(steps),
        ExecMode::Remote { peers: vec!["proc".into(), "proc".into()] },
        fast_opts(),
    );
    assert_matches_serial(n, &serial, &remote_run, "proc x2");
}

/// The fault-tolerance acceptance: one peer killed mid-row (exit(9)
/// after the first frame of its first row) — the orphaned row is
/// re-dispatched to the healthy peer and the sweep is still
/// bit-identical to serial. The aborted attempt's partial events are
/// discarded, never fanned out.
#[test]
fn killed_peer_mid_row_redispatches_bit_identically() {
    let steps = 5;
    let n = micro_specs(steps).len();
    let serial = run_mode(
        micro_specs(steps),
        ExecMode::Threads { workers: 1 },
        RemoteOpts::default(),
    );

    let exe = Path::new(WORKER_EXE);
    let dying = remote::spawn_serve_worker(exe, &["--die-mid-row", "1"]).expect("spawn dying");
    let healthy = remote::spawn_serve_worker(exe, &[]).expect("spawn healthy");
    let remote_run = run_mode(
        micro_specs(steps),
        ExecMode::Remote { peers: vec![dying.addr.clone(), healthy.addr.clone()] },
        fast_opts(),
    );
    assert_matches_serial(n, &serial, &remote_run, "kill mid-row");

    // The kill actually happened: some row was requeued off the dying
    // peer and re-dispatched on a later attempt.
    let requeued = remote_run
        .1
        .iter()
        .any(|e| matches!(e, TrainEvent::RowRequeued { peer, .. } if *peer == dying.addr));
    assert!(requeued, "dying peer never lost a row — test hook inert?");
    let retried = remote_run
        .1
        .iter()
        .any(|e| matches!(e, TrainEvent::RowDispatched { attempt, .. } if *attempt > 1));
    assert!(retried, "no re-dispatch attempt observed");
}

/// A version-skewed peer is refused at the hello — and with a healthy
/// peer beside it the sweep still completes, bit-identical to serial.
#[test]
fn version_skewed_peer_is_refused_but_sweep_survives() {
    let skewed = fake_peer(|mut s| {
        let hello = WireHello {
            proto: wire::WIRE_VERSION + 41,
            peer: "old-build".into(),
            backends: vec!["native".into()],
        };
        let _ = remote::write_frame(&mut s, &wire::encode_hello(&hello));
    });

    // Direct connect: the refusal names the skew.
    let timeout = Duration::from_secs(2);
    let err = remote::TcpTransport::connect(&skewed, timeout, timeout)
        .expect_err("skewed hello accepted");
    assert!(
        format!("{err:#}").contains("version-skewed"),
        "refusal does not name the skew: {err:#}"
    );

    let steps = 3;
    let specs = || micro_specs(steps)[..2].to_vec();
    let serial = run_mode(specs(), ExecMode::Threads { workers: 1 }, RemoteOpts::default());
    let healthy = remote::spawn_serve_worker(Path::new(WORKER_EXE), &[]).expect("spawn healthy");
    let remote_run = run_mode(
        specs(),
        ExecMode::Remote { peers: vec![skewed, healthy.addr.clone()] },
        fast_opts(),
    );
    assert_matches_serial(2, &serial, &remote_run, "skewed + healthy");
    // Every completed dispatch landed on the healthy peer.
    for ev in &remote_run.1 {
        if let TrainEvent::RowDispatched { peer, .. } = ev {
            assert_eq!(*peer, healthy.addr, "row dispatched to the skewed peer");
        }
    }
}

/// A hung peer — valid hello, then silence — times out at the idle
/// bound; the row is re-dispatched to the healthy peer and the sweep
/// still matches serial. This also pins the balancer's pessimistic
/// penalty: without it the unmeasured hung peer would rank first and
/// win every re-dispatch of the same row until its attempts ran out.
#[test]
fn hung_peer_times_out_and_healthy_peer_absorbs_the_row() {
    let hung = fake_peer(|mut s| {
        let hello = WireHello {
            proto: wire::WIRE_VERSION,
            peer: "hung".into(),
            backends: vec!["native".into()],
        };
        let _ = remote::write_frame(&mut s, &wire::encode_hello(&hello));
        // Hold the connection open, sending nothing: reads on the
        // coordinator side must hit the idle timeout, not EOF.
        std::thread::sleep(Duration::from_secs(30));
    });
    let healthy = remote::spawn_serve_worker(Path::new(WORKER_EXE), &[]).expect("spawn healthy");

    let steps = 3;
    let specs = || micro_specs(steps)[..2].to_vec();
    let serial = run_mode(specs(), ExecMode::Threads { workers: 1 }, RemoteOpts::default());
    let opts = RemoteOpts { idle_timeout: Duration::from_millis(700), ..fast_opts() };
    let remote_run = run_mode(
        specs(),
        ExecMode::Remote { peers: vec![hung, healthy.addr.clone()] },
        opts,
    );
    assert_matches_serial(2, &serial, &remote_run, "hung + healthy");
    let timed_out = remote_run
        .1
        .iter()
        .any(|e| matches!(e, TrainEvent::RowRequeued { peer, .. } if *peer != healthy.addr));
    assert!(timed_out, "hung peer never timed out a row");
}

/// Row-level errors stay deterministic under remote dispatch: the
/// failing row surfaces as first-error-by-spec-index with its label,
/// and is dispatched exactly once — error frames are never retried.
#[test]
fn row_error_is_spec_indexed_and_never_retried() {
    let exe = Path::new(WORKER_EXE);
    let a = remote::spawn_serve_worker(exe, &[]).expect("spawn peer a");
    let b = remote::spawn_serve_worker(exe, &[]).expect("spawn peer b");

    let mut specs = micro_specs(3);
    let mut bad = TrainConfig::default();
    bad.model = "no_such_model".into();
    bad.steps = 3;
    specs.insert(1, RunSpec::new("broken-row", bad));

    let rt = backend();
    let sink = Arc::new(CollectSink::default());
    let err = Sweep::new(specs)
        .mode(ExecMode::Remote { peers: vec![a.addr.clone(), b.addr.clone()] })
        .remote_opts(fast_opts())
        .events(sink.clone())
        .run(&rt)
        .expect_err("broken row succeeded");
    let msg = format!("{err:#}");
    assert!(msg.contains("sweep row 1"), "error lacks spec index: {msg}");
    assert!(msg.contains("broken-row"), "error lacks spec label: {msg}");
    assert!(msg.contains("worker failed"), "error lost the worker verdict: {msg}");

    let events = sink.take();
    let broken_dispatches = events
        .iter()
        .filter(|e| matches!(e, TrainEvent::RowDispatched { run: 1, .. }))
        .count();
    assert_eq!(broken_dispatches, 1, "deterministic row failure was retried");
    assert!(
        !events.iter().any(|e| matches!(e, TrainEvent::RowRequeued { run: 1, .. })),
        "deterministic row failure was requeued"
    );
}

/// A row whose backend no live peer advertises fails cleanly (naming
/// the backend) instead of deadlocking the scheduler, and the peers'
/// hellos — not coordinator guesswork — are what decide routability.
#[test]
fn unroutable_backend_fails_instead_of_deadlocking() {
    let exe = Path::new(WORKER_EXE);
    let peer = remote::spawn_serve_worker(exe, &[]).expect("spawn peer");
    // serve-worker advertises native-only unless built with the xla
    // feature — in which case this scenario can't arise and the test
    // has nothing to pin.
    if cfg!(feature = "xla") {
        return;
    }
    let mut xla_row = mk("needs-xla", "lm_micro", OptKind::Coap, 2);
    xla_row.cfg.backend = BackendKind::Xla;

    let rt = backend();
    let err = Sweep::new(vec![xla_row])
        .mode(ExecMode::Remote { peers: vec![peer.addr.clone()] })
        .remote_opts(fast_opts())
        .run(&rt)
        .expect_err("unroutable row succeeded");
    let msg = format!("{err:#}");
    assert!(msg.contains("sweep row 0"), "{msg}");
    assert!(
        msg.contains("backend 'xla'") || msg.contains("supports backend"),
        "error does not name the unroutable backend: {msg}"
    );
}
