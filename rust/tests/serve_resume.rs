//! Acceptance suite for the resident sweep scheduler (`coap serve`):
//! a journaled job killed mid-run (SIGKILL semantics — the daemon
//! exits without unwinding straight after fsyncing a row) and resumed
//! by a fresh daemon must produce spec-ordered reports **bit-identical**
//! to serial in-process execution, re-running only the rows whose
//! reports were not yet journaled. Plus the service surface: bounded-
//! queue backpressure refuses (and does not journal) excess submits,
//! status reflects the queue, finished jobs replay their reports from
//! the journal alone, and graceful shutdown exits clean.
//!
//! The daemon is the real `coap` CLI (CARGO_BIN_EXE_coap) speaking the
//! real TCP framing with real `coap worker` subprocess peers, so this
//! suite pins `coap serve` + `coap submit` end to end.

use coap::config::{OptKind, TrainConfig};
use coap::coordinator::serve::{self, spawn_serve, DaemonHandle};
use coap::coordinator::wire::JobSpec;
use coap::coordinator::{ExecMode, RunSpec, Sweep, TrainReport};
use coap::runtime::{Backend, NativeBackend};
use coap::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

const EXE: &str = env!("CARGO_BIN_EXE_coap");
const TIMEOUT: Duration = Duration::from_secs(10);

fn state_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("coap_serve_resume_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn mk(label: &str, model: &str, opt: OptKind, steps: usize) -> RunSpec {
    let mut c = TrainConfig::default();
    c.model = model.into();
    c.optimizer = opt;
    c.steps = steps;
    c.lr = 3e-3;
    c.t_update = 2;
    c.lambda = 2;
    c.eval_every = steps;
    c.eval_batches = 1;
    c.log_every = 0;
    RunSpec::new(label, c)
}

/// Three micro rows — enough that `--die-after-rows 1` provably leaves
/// unfinished work behind for the resumed daemon.
fn micro_specs() -> Vec<RunSpec> {
    vec![
        mk("coap/lm", "lm_micro", OptKind::Coap, 3),
        mk("adamw/lm", "lm_micro", OptKind::AdamW, 3),
        mk("coap/vit", "vit_micro", OptKind::Coap, 3),
    ]
}

/// Everything deterministic in a report, floats as raw bits (measured
/// wall-clock fields excluded) — the same comparison the remote-sweep
/// parity suite pins.
type RowKey = (String, Vec<(usize, u64)>, Vec<u64>, usize, usize);

fn row_key(r: &TrainReport) -> RowKey {
    (
        r.label.clone(),
        r.train_losses.iter().map(|(s, l)| (*s, l.to_bits())).collect(),
        r.evals.iter().map(|e| e.loss.to_bits()).collect(),
        r.optimizer_bytes,
        r.param_bytes,
    )
}

/// All parseable `{"t":"row"}` journal entries as `(job, row, line)`.
/// An unparseable line is tolerated only at the tail (a SIGKILL can
/// tear the final append — replay drops it, and so do we).
fn journal_rows(dir: &Path) -> Vec<(u64, usize, String)> {
    let data = std::fs::read_to_string(dir.join("journal.jsonl")).expect("journal exists");
    let lines: Vec<&str> = data.lines().collect();
    let mut rows = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        let Ok(j) = Json::parse(line) else {
            assert_eq!(i, lines.len() - 1, "only the final journal line may be torn: {line:?}");
            continue;
        };
        if j.get("t").and_then(|t| t.as_str()) == Some("row") {
            rows.push((
                j.get("job").and_then(|v| v.as_usize()).expect("row entry has job") as u64,
                j.get("row").and_then(|v| v.as_usize()).expect("row entry has row"),
                line.to_string(),
            ));
        }
    }
    rows
}

fn submit_micro(addr: &str) -> u64 {
    let job = JobSpec { name: "micro".into(), priority: 0, specs: micro_specs() };
    let ack = serve::client_submit(addr, &job, TIMEOUT).expect("submit");
    assert!(ack.accepted, "submit refused: {}", ack.reason);
    ack.job
}

/// The PR's acceptance test: kill the daemon right after it journals
/// its first row report, restart it on the same state dir, and require
/// (a) the resumed job's reports bit-identical to serial in-process
/// execution, (b) journaled rows served verbatim from the journal
/// rather than re-run, and (c) a finished job replayable from the
/// journal alone by yet another daemon.
#[test]
fn killed_daemon_resumes_bit_identical_to_serial() {
    let dir = state_dir("kill");
    // Serial baseline, same specs, in this process.
    let rt: Arc<dyn Backend> = Arc::new(NativeBackend::new());
    let serial = Sweep::new(micro_specs())
        .mode(ExecMode::Threads { workers: 1 })
        .run(&rt)
        .expect("serial baseline");
    let serial_keys: Vec<RowKey> = serial.iter().map(row_key).collect();

    // Daemon #1: dies without unwinding straight after fsyncing the
    // first row report — the crash the journal exists for.
    let mut d1 = spawn_serve(
        Path::new(EXE),
        &dir,
        &["--peers", "proc,proc", "--die-after-rows", "1"],
    )
    .expect("spawn daemon 1");
    let job = submit_micro(&d1.addr);
    let status = d1.wait_exit().expect("daemon 1 exit");
    assert_eq!(status.code(), Some(9), "daemon must die via the exit(9) hook");
    let before = journal_rows(&dir);
    assert!(
        !before.is_empty() && before.len() < micro_specs().len(),
        "the kill must land mid-job: {} of {} rows journaled",
        before.len(),
        micro_specs().len()
    );

    // Daemon #2: replays the journal, resumes the job, runs only the
    // missing rows. Watching the job blocks to its terminal frame.
    let mut d2 =
        spawn_serve(Path::new(EXE), &dir, &["--peers", "proc,proc"]).expect("spawn daemon 2");
    let reports = serve::client_watch(&d2.addr, job, TIMEOUT, None).expect("resumed job");
    let resumed_keys: Vec<RowKey> = reports.iter().map(row_key).collect();
    assert_eq!(
        resumed_keys, serial_keys,
        "resumed reports drifted from the serial baseline"
    );

    // The journal must hold exactly one report per row — a duplicate
    // (job, row) pair would mean a completed row was re-run.
    let after = journal_rows(&dir);
    let mut pairs: Vec<(u64, usize)> = after.iter().map(|(j, r, _)| (*j, *r)).collect();
    pairs.sort_unstable();
    pairs.dedup();
    assert_eq!(
        pairs.len(),
        after.len(),
        "duplicate journal row entries: a completed row was re-run"
    );
    assert_eq!(after.len(), micro_specs().len(), "one journaled report per row");
    // Pre-kill rows must survive byte-for-byte: the resumed daemon
    // serves them from the journal, it does not recompute them.
    for (j, r, line) in &before {
        assert!(
            after.iter().any(|(aj, ar, al)| aj == j && ar == r && al == line),
            "journaled report for row {r} was rewritten by the resumed daemon"
        );
    }
    // Status agrees: the job is done, all rows accounted for.
    let jobs = serve::client_status(&d2.addr, TIMEOUT).expect("status");
    let js = jobs.iter().find(|s| s.job == job).expect("job in status");
    assert_eq!((js.state.as_str(), js.rows_done, js.rows_total), ("done", 3, 3));

    // Daemon #3: a finished job replays entirely from the journal —
    // same bits, no peers ever contacted (a bad pool would fail rows,
    // not replay). SIGKILL d2 first; its journal is already durable.
    d2.kill();
    let d3 = spawn_serve(Path::new(EXE), &dir, &["--peers", "proc"]).expect("spawn daemon 3");
    let replayed = serve::client_watch(&d3.addr, job, TIMEOUT, None).expect("replayed job");
    let replayed_keys: Vec<RowKey> = replayed.iter().map(row_key).collect();
    assert_eq!(replayed_keys, serial_keys, "journal replay drifted");
    drop(d3);
    std::fs::remove_dir_all(&dir).ok();
}

/// Bounded-queue backpressure: a daemon with `--queue-max 0` refuses
/// every submission in the ack and journals nothing — the refusal is
/// advisory, not a crash, and the daemon stays serviceable.
#[test]
fn full_queue_refuses_submit_without_journaling() {
    let dir = state_dir("backpressure");
    let d = spawn_serve(Path::new(EXE), &dir, &["--peers", "proc", "--queue-max", "0"])
        .expect("spawn daemon");
    let job = JobSpec { name: "micro".into(), priority: 0, specs: micro_specs() };
    let ack = serve::client_submit(&d.addr, &job, TIMEOUT).expect("submit completes");
    assert!(!ack.accepted, "queue-max 0 must refuse");
    assert!(ack.reason.contains("queue full"), "reason: {}", ack.reason);
    // Not journaled, and the daemon still answers.
    assert!(serve::client_status(&d.addr, TIMEOUT).expect("status").is_empty());
    let journal = std::fs::read_to_string(dir.join("journal.jsonl")).unwrap_or_default();
    assert!(
        !journal.contains("\"t\":\"submit\""),
        "a refused submit must not reach the journal"
    );
    drop(d);
    std::fs::remove_dir_all(&dir).ok();
}

/// An empty job is refused outright (nothing to journal or run), and a
/// watch of an unknown job fails cleanly instead of hanging.
#[test]
fn degenerate_requests_fail_cleanly() {
    let dir = state_dir("degenerate");
    let d = spawn_serve(Path::new(EXE), &dir, &["--peers", "proc"]).expect("spawn daemon");
    let empty = JobSpec { name: "empty".into(), priority: 0, specs: vec![] };
    let ack = serve::client_submit(&d.addr, &empty, TIMEOUT).expect("submit completes");
    assert!(!ack.accepted, "an empty job must be refused");
    let err = serve::client_watch(&d.addr, 777, TIMEOUT, None)
        .expect_err("watching an unknown job must fail");
    assert!(format!("{err:#}").contains("unknown job"), "{err:#}");
    drop(d);
    std::fs::remove_dir_all(&dir).ok();
}

/// Graceful shutdown: the daemon exits 0 on request; the journal makes
/// the timing immaterial.
#[test]
fn shutdown_request_exits_clean() {
    let dir = state_dir("shutdown");
    let mut d: DaemonHandle =
        spawn_serve(Path::new(EXE), &dir, &["--peers", "proc"]).expect("spawn daemon");
    serve::client_shutdown(&d.addr, TIMEOUT).expect("shutdown send");
    let status = d.wait_exit().expect("daemon exit");
    assert_eq!(status.code(), Some(0), "graceful shutdown must exit 0");
    std::fs::remove_dir_all(&dir).ok();
}
