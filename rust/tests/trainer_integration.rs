//! End-to-end integration on the hermetic native backend: real training
//! loop, real optimizer state machines, native fwd/bwd — and training
//! actually LEARNS (loss decreases) under each optimizer family.
//! (The same suite ran against PJRT artifacts before the backend split;
//! with `--features xla` the xla-gated tests cover that engine.)

use coap::config::{OptKind, TrainConfig};
use coap::coordinator::checkpoint::Checkpoint;
use coap::coordinator::Trainer;
use coap::runtime::{Backend, NativeBackend};
use coap::tensor::Precision;
use std::sync::Arc;

fn backend() -> Arc<dyn Backend> {
    Arc::new(NativeBackend::new())
}

fn cfg(opt: OptKind, steps: usize) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.model = "lm_tiny".into();
    c.optimizer = opt;
    c.steps = steps;
    c.lr = 3e-3;
    c.t_update = 5;
    c.lambda = 4;
    c.eval_every = 0;
    c.log_every = 0;
    c.track_ceu = true;
    c
}

fn run(c: TrainConfig, rt: Arc<dyn Backend>) -> coap::coordinator::TrainReport {
    let mut tr = Trainer::builder(c).backend(rt).quiet().build().unwrap();
    tr.run().unwrap()
}

#[test]
fn coap_training_reduces_loss() {
    let rt = backend();
    let rep = run(cfg(OptKind::Coap, 40), rt);
    let first = rep.train_losses[0].1;
    let last = rep.final_train_loss;
    assert!(
        last < first - 0.2,
        "loss did not drop: {first:.3} -> {last:.3}"
    );
    assert!(rep.ceu_total > 0.0);
    assert!(rep.optimizer_bytes > 0);
}

#[test]
fn all_optimizers_train_and_report_memory_ordering() {
    let rt = backend();
    let mut reports = Vec::new();
    for opt in [
        OptKind::AdamW,
        OptKind::Adafactor,
        OptKind::Coap,
        OptKind::Galore,
        OptKind::Flora,
        OptKind::Lora,
    ] {
        let rep = run(cfg(opt, 12), Arc::clone(&rt));
        let first = rep.train_losses[0].1;
        assert!(
            rep.final_train_loss < first,
            "{:?} did not reduce loss ({first:.3} -> {:.3})",
            opt,
            rep.final_train_loss
        );
        reports.push((opt, rep));
    }
    let bytes = |k: OptKind| {
        reports
            .iter()
            .find(|(o, _)| *o == k)
            .map(|(_, r)| r.optimizer_bytes)
            .unwrap()
    };
    // Paper's memory ordering: low-rank < Adafactor < AdamW.
    assert!(bytes(OptKind::Coap) < bytes(OptKind::AdamW));
    assert!(bytes(OptKind::Galore) < bytes(OptKind::AdamW));
    assert!(bytes(OptKind::Adafactor) < bytes(OptKind::AdamW));
    // COAP and GaLore share state shapes -> identical footprint.
    assert_eq!(bytes(OptKind::Coap), bytes(OptKind::Galore));
}

#[test]
fn int8_state_cuts_optimizer_memory() {
    let rt = backend();
    let f32_rep = run(cfg(OptKind::Coap, 25), Arc::clone(&rt));
    let mut c8 = cfg(OptKind::Coap, 25);
    c8.state_precision = Precision::Int8;
    let i8_rep = run(c8, rt);
    // Moments shrink ~4x; projections stay f32, so overall ratio > 2x.
    let ratio = f32_rep.optimizer_bytes as f64 / i8_rep.optimizer_bytes as f64;
    assert!(ratio > 2.0, "int8 ratio only {ratio:.2}");
    // ...and it still trains (quantized moments add noise; allow slack
    // vs the f32 run but require a real loss drop).
    assert!(
        i8_rep.final_train_loss < i8_rep.train_losses[0].1 - 0.1,
        "int8 loss {:.3} -> {:.3}",
        i8_rep.train_losses[0].1,
        i8_rep.final_train_loss
    );
}

#[test]
fn eval_reports_ppl() {
    let rt = backend();
    let mut c = cfg(OptKind::Coap, 10);
    c.eval_every = 10;
    c.eval_batches = 2;
    let rep = run(c, rt);
    let ev = &rep.final_eval;
    assert!(ev.loss > 0.0 && ev.ppl > 1.0);
    assert!((ev.ppl - ev.loss.exp()).abs() < 1e-9);
}

#[test]
fn deterministic_given_seed() {
    let rt = backend();
    let a = run(cfg(OptKind::Coap, 8), Arc::clone(&rt));
    let b = run(cfg(OptKind::Coap, 8), rt);
    assert_eq!(a.train_losses, b.train_losses);
    assert_eq!(a.ceu_total, b.ceu_total);
}

/// The parallel per-slot loop must be thread-count-invariant: per-slot
/// RNG streams are forked from (seed, step, slot), so a 1-worker run and
/// an 8-worker run produce bit-identical trajectories.
#[test]
fn deterministic_across_thread_counts() {
    let rt = backend();
    let mut c1 = cfg(OptKind::Coap, 8);
    c1.threads = 1;
    let mut cn = cfg(OptKind::Coap, 8);
    cn.threads = 8;
    let a = run(c1, Arc::clone(&rt));
    let b = run(cn, Arc::clone(&rt));
    assert_eq!(a.train_losses, b.train_losses);
    assert_eq!(a.ceu_total, b.ceu_total);
    // Same for a resampling policy (Flora draws fresh projections from
    // the per-slot streams every refresh).
    let mut f1 = cfg(OptKind::Flora, 8);
    f1.threads = 1;
    f1.t_update = 2;
    let mut fnn = f1.clone();
    fnn.threads = 6;
    let fa = run(f1, Arc::clone(&rt));
    let fb = run(fnn, rt);
    assert_eq!(fa.train_losses, fb.train_losses);
}

/// Checkpoint round-trip through the builder `resume()` path: the
/// restored parameters must be bit-identical both to the trained
/// parameters and to the manual `Checkpoint::into_params_for` injection
/// (the old `main.rs` field-poking path the builder replaced).
#[test]
fn builder_resume_matches_manual_param_injection() {
    let rt = backend();
    let c = cfg(OptKind::Coap, 6);
    let mut tr = Trainer::builder(c.clone())
        .backend(Arc::clone(&rt))
        .quiet()
        .build()
        .unwrap();
    tr.run().unwrap();

    let dir = std::env::temp_dir().join(format!("coap_resume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resume.ckpt");
    let path = path.to_str().unwrap();
    tr.save_checkpoint(path).unwrap();

    let mut tr2 = Trainer::builder(c)
        .backend(Arc::clone(&rt))
        .quiet()
        .resume(path)
        .build()
        .unwrap();
    assert_eq!(tr2.resume_info().map(|(_, step)| step), Some(6));

    let ck = Checkpoint::load(path).unwrap();
    assert_eq!(ck.step, 6);
    let manual = ck.into_params_for(tr2.model()).unwrap();
    assert_eq!(tr2.params().len(), manual.len());
    for (i, (a, b)) in tr2.params().iter().zip(manual.iter()).enumerate() {
        let (ab, bb): (Vec<u32>, Vec<u32>) = (
            a.f32s().iter().map(|v| v.to_bits()).collect(),
            b.f32s().iter().map(|v| v.to_bits()).collect(),
        );
        assert_eq!(ab, bb, "param {i} drifted vs manual injection");
    }
    for (i, (a, b)) in tr2.params().iter().zip(tr.params().iter()).enumerate() {
        assert_eq!(a.f32s(), b.f32s(), "param {i} drifted vs trained state");
    }

    // Checkpoint steps accumulate across resume chains: 6 resumed + 6
    // trained saves step 12, not a reset to 6.
    tr2.run().unwrap();
    let path2 = dir.join("resume2.ckpt");
    let path2 = path2.to_str().unwrap();
    tr2.save_checkpoint(path2).unwrap();
    assert_eq!(Checkpoint::load(path2).unwrap().step, 12);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn micro_models_train_on_every_family() {
    let rt = backend();
    for (model, lr) in [
        ("lm_micro", 3e-3f32),
        ("vit_micro", 3e-3),
        ("cnn_micro", 3e-3),
        ("ctrl_micro", 3e-3),
        ("sit_micro", 3e-3),
        ("llava_micro", 3e-3),
    ] {
        let mut c = cfg(OptKind::Coap, 12);
        c.model = model.into();
        c.lr = lr;
        c.t_update = 3;
        c.lambda = 2;
        let rep = run(c, Arc::clone(&rt));
        assert!(
            rep.final_train_loss.is_finite()
                && rep.final_train_loss < rep.train_losses[0].1,
            "{model}: {:.4} -> {:.4}",
            rep.train_losses[0].1,
            rep.final_train_loss
        );
    }
}
