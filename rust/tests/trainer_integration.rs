//! End-to-end integration: real artifacts, real PJRT, real training.
//! Verifies the whole three-layer stack composes — and that training
//! actually LEARNS (loss decreases) under each optimizer family.

use coap::config::{default_artifacts_dir, OptKind, TrainConfig};
use coap::coordinator::Trainer;
use coap::runtime::Runtime;
use coap::tensor::Precision;
use std::sync::Arc;

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::open(&default_artifacts_dir()).expect("make artifacts first"))
}

fn cfg(opt: OptKind, steps: usize) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.model = "lm_tiny".into();
    c.optimizer = opt;
    c.steps = steps;
    c.lr = 3e-3;
    c.t_update = 5;
    c.lambda = 4;
    c.eval_every = 0;
    c.log_every = 0;
    c.track_ceu = true;
    c
}

fn run(c: TrainConfig, rt: Arc<Runtime>) -> coap::coordinator::TrainReport {
    let mut tr = Trainer::new(c, rt).unwrap();
    tr.quiet = true;
    tr.run().unwrap()
}

#[test]
fn coap_training_reduces_loss() {
    let rt = runtime();
    let rep = run(cfg(OptKind::Coap, 40), rt);
    let first = rep.train_losses[0].1;
    let last = rep.final_train_loss;
    assert!(
        last < first - 0.5,
        "loss did not drop: {first:.3} -> {last:.3}"
    );
    assert!(rep.ceu_total > 0.0);
    assert!(rep.optimizer_bytes > 0);
}

#[test]
fn all_optimizers_train_and_report_memory_ordering() {
    let rt = runtime();
    let mut reports = Vec::new();
    for opt in [
        OptKind::AdamW,
        OptKind::Adafactor,
        OptKind::Coap,
        OptKind::Galore,
        OptKind::Flora,
        OptKind::Lora,
    ] {
        let rep = run(cfg(opt, 12), Arc::clone(&rt));
        let first = rep.train_losses[0].1;
        assert!(
            rep.final_train_loss < first,
            "{:?} did not reduce loss ({first:.3} -> {:.3})",
            opt,
            rep.final_train_loss
        );
        reports.push((opt, rep));
    }
    let bytes = |k: OptKind| {
        reports
            .iter()
            .find(|(o, _)| *o == k)
            .map(|(_, r)| r.optimizer_bytes)
            .unwrap()
    };
    // Paper's memory ordering: low-rank < Adafactor < AdamW.
    assert!(bytes(OptKind::Coap) < bytes(OptKind::AdamW));
    assert!(bytes(OptKind::Galore) < bytes(OptKind::AdamW));
    assert!(bytes(OptKind::Adafactor) < bytes(OptKind::AdamW));
    // COAP and GaLore share state shapes -> identical footprint.
    assert_eq!(bytes(OptKind::Coap), bytes(OptKind::Galore));
}

#[test]
fn int8_state_cuts_optimizer_memory() {
    let rt = runtime();
    let f32_rep = run(cfg(OptKind::Coap, 25), Arc::clone(&rt));
    let mut c8 = cfg(OptKind::Coap, 25);
    c8.state_precision = Precision::Int8;
    let i8_rep = run(c8, rt);
    // Moments shrink ~4x; projections stay f32, so overall ratio > 2x.
    let ratio = f32_rep.optimizer_bytes as f64 / i8_rep.optimizer_bytes as f64;
    assert!(ratio > 2.0, "int8 ratio only {ratio:.2}");
    // ...and it still trains (quantized moments add noise; allow slack
    // vs the f32 run but require a real loss drop).
    assert!(
        i8_rep.final_train_loss < i8_rep.train_losses[0].1 - 0.2,
        "int8 loss {:.3} -> {:.3}",
        i8_rep.train_losses[0].1,
        i8_rep.final_train_loss
    );
}

#[test]
fn eval_reports_ppl() {
    let rt = runtime();
    let mut c = cfg(OptKind::Coap, 10);
    c.eval_every = 10;
    c.eval_batches = 2;
    let rep = run(c, rt);
    let ev = &rep.final_eval;
    assert!(ev.loss > 0.0 && ev.ppl > 1.0);
    assert!((ev.ppl - ev.loss.exp()).abs() < 1e-9);
}

#[test]
fn deterministic_given_seed() {
    let rt = runtime();
    let a = run(cfg(OptKind::Coap, 8), Arc::clone(&rt));
    let b = run(cfg(OptKind::Coap, 8), rt);
    assert_eq!(a.train_losses, b.train_losses);
    assert_eq!(a.ceu_total, b.ceu_total);
}
