//! Integration smoke: load real artifacts, compile via PJRT, execute,
//! and sanity-check numerics. Requires the `xla` feature and
//! `make artifacts`; skipped entirely on the hermetic default build.
#![cfg(feature = "xla")]

use coap::config::default_artifacts_dir;
use coap::rng::Rng;
use coap::runtime::{Backend, Runtime};
use coap::tensor::Tensor;

fn runtime() -> Runtime {
    Runtime::open(&default_artifacts_dir()).expect("run `make artifacts` first")
}

#[test]
fn recalib_returns_orthonormal_projection() {
    let rt = runtime();
    let mut rng = Rng::new(0);
    // recalib__128x128_r32: inputs (P (128,32), G (128,128)) -> P' (128,32)
    let p = {
        // Random near-orthonormal start: normalize random gaussian columns.
        let mut data = rng.normal_vec(128 * 32, 1.0);
        for j in 0..32 {
            let mut norm = 0.0f32;
            for i in 0..128 {
                norm += data[i * 32 + j] * data[i * 32 + j];
            }
            let norm = norm.sqrt().max(1e-6);
            for i in 0..128 {
                data[i * 32 + j] /= norm;
            }
        }
        Tensor::from_f32(&[128, 32], data)
    };
    let g = Tensor::from_f32(&[128, 128], rng.normal_vec(128 * 128, 1.0));
    let out = rt.exec("recalib__128x128_r32", &[&p, &g]).unwrap();
    assert_eq!(out.len(), 1);
    let pnew = &out[0];
    assert_eq!(pnew.dims(), &[128, 32]);
    // Columns of P' should be orthonormal: P'^T P' ~ I.
    let gram = pnew.transposed2d().matmul(pnew);
    for i in 0..32 {
        for j in 0..32 {
            let want = if i == j { 1.0 } else { 0.0 };
            let got = gram.f32s()[i * 32 + j];
            assert!(
                (got - want).abs() < 5e-2,
                "gram[{i},{j}] = {got}, want {want}"
            );
        }
    }
}

#[test]
fn adam_step_moves_weights_against_gradient() {
    let rt = runtime();
    let mut rng = Rng::new(1);
    let dims = [128usize, 128usize];
    let n = 128 * 128;
    let w = Tensor::from_f32(&dims, rng.normal_vec(n, 0.1));
    let g = Tensor::from_f32(&dims, vec![1.0; n]); // uniform positive grad
    let m = Tensor::zeros(&dims);
    let v = Tensor::zeros(&dims);
    let b1t = Tensor::scalar_f32(0.9);
    let b2t = Tensor::scalar_f32(0.999);
    let lr = Tensor::scalar_f32(0.01);
    let wd = Tensor::scalar_f32(0.0);
    let out = rt
        .exec("adam_step__128x128", &[&w, &g, &m, &v, &b1t, &b2t, &lr, &wd])
        .unwrap();
    assert_eq!(out.len(), 4);
    let w_new = &out[0];
    // With g > 0 everywhere and fresh moments, every weight decreases by
    // ~lr (bias-corrected Adam step of a constant gradient is ~1.0 * lr).
    let mut moved = 0;
    for (a, b) in w_new.f32s().iter().zip(w.f32s()) {
        if b - a > 0.005 {
            moved += 1;
        }
    }
    assert!(moved > n * 9 / 10, "only {moved}/{n} weights moved down");
    // CEU output is a positive scalar ~ n * lr.
    let ceu = out[3].scalar();
    assert!(ceu > 0.0 && ceu < (n as f32) * 0.011, "ceu={ceu}");
}

#[test]
fn train_step_lm_tiny_returns_finite_loss_and_grads() {
    let rt = runtime();
    let mut rng = Rng::new(2);
    let model = rt.manifest.model("lm_tiny").unwrap().clone();
    // Build params per census.
    let mut inputs: Vec<Tensor> = Vec::new();
    for p in &model.params {
        let t = match p.init.as_str() {
            "ones" => Tensor::from_f32(&p.shape, vec![1.0; p.numel()]),
            "zeros" => Tensor::zeros(&p.shape),
            _ => Tensor::from_f32(&p.shape, rng.normal_vec(p.numel(), p.scale)),
        };
        inputs.push(t);
    }
    let vocab = model.cfg_usize("vocab");
    for d in &model.data {
        let n: usize = d.shape.iter().product();
        let t = match d.dtype.as_str() {
            "i32" => Tensor::from_i32(
                &d.shape,
                (0..n).map(|_| rng.below(vocab) as i32).collect(),
            ),
            _ => Tensor::from_f32(&d.shape, rng.normal_vec(n, 1.0)),
        };
        inputs.push(t);
    }
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let out = rt.exec(&model.train_step, &refs).unwrap();
    assert_eq!(out.len(), 1 + model.params.len());
    let loss = out[0].scalar();
    // Random init on vocab-512: loss ~ ln(512) ~ 6.24.
    assert!(loss.is_finite() && loss > 3.0 && loss < 10.0, "loss={loss}");
    for (g, p) in out[1..].iter().zip(&model.params) {
        assert_eq!(g.dims(), &p.shape[..], "grad shape for {}", p.name);
        assert!(g.f32s().iter().all(|v| v.is_finite()), "grad {} finite", p.name);
    }
    // At least the head/embed grads should be non-zero.
    assert!(out[1].l1_norm() > 0.0);
}
