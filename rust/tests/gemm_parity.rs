//! Property suite for the shared kernel layer (`tensor::linalg`): the
//! blocked/SIMD NN/TN/NT GEMMs must agree with the naive triple-loop
//! oracle across adversarial shapes (1x1, primes, m >> n, n >> m), the
//! `*_into` variants must fully overwrite stale buffers, and the
//! pool-parallel path must be bit-identical to serial for any worker
//! count — the kernel-layer extension of PR 1's thread-count-invariance
//! contract.

use coap::rng::Rng;
use coap::tensor::linalg;
use coap::util::threadpool::ThreadPool;

/// |got - want| <= tol elementwise (FP-order drift between the blocked
/// core and the oracle is ~1e-5 at these depths; 1e-3 has wide margin).
fn assert_close(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!((g - w).abs() <= tol, "{ctx}: idx {i}: got {g}, want {w}");
    }
}

const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 7, 1),
    (3, 1, 5),
    (2, 2, 2),
    (5, 3, 2),
    (7, 13, 11),
    (17, 17, 17),
    (31, 63, 33),
    (64, 64, 64),
    (65, 129, 67),
    (128, 40, 96),
    (200, 3, 1),    // m >> n
    (1, 5, 190),    // n >> m
    (150, 257, 5),  // k spanning two KC blocks
    (3, 300, 3),    // deep and skinny
];

#[test]
fn gemm_nn_matches_naive_oracle() {
    let mut rng = Rng::new(101);
    for &(m, k, n) in SHAPES {
        let a = rng.normal_vec(m * k, 0.5);
        let b = rng.normal_vec(k * n, 0.5);
        let want = linalg::naive_matmul(&a, &b, m, k, n);
        let got = linalg::gemm_nn(None, &a, &b, m, k, n);
        assert_close(&got, &want, 1e-3, &format!("nn {m}x{k}x{n}"));
    }
}

#[test]
fn gemm_tn_matches_transposed_oracle() {
    let mut rng = Rng::new(102);
    for &(m, k, n) in SHAPES {
        // a stored (k, m): gemm_tn computes aᵀ·b = (m, k)·(k, n).
        let a = rng.normal_vec(k * m, 0.5);
        let b = rng.normal_vec(k * n, 0.5);
        let at = linalg::transpose(&a, k, m);
        let want = linalg::naive_matmul(&at, &b, m, k, n);
        let got = linalg::gemm_tn(None, &a, &b, k, m, n);
        assert_close(&got, &want, 1e-3, &format!("tn {m}x{k}x{n}"));
    }
}

#[test]
fn gemm_nt_matches_transposed_oracle() {
    let mut rng = Rng::new(103);
    for &(m, k, n) in SHAPES {
        // b stored (n, k): gemm_nt computes a·bᵀ = (m, k)·(k, n).
        let a = rng.normal_vec(m * k, 0.5);
        let b = rng.normal_vec(n * k, 0.5);
        let bt = linalg::transpose(&b, n, k);
        let want = linalg::naive_matmul(&a, &bt, m, k, n);
        let got = linalg::gemm_nt(None, &a, &b, m, k, n);
        assert_close(&got, &want, 1e-3, &format!("nt {m}x{k}x{n}"));
    }
}

#[test]
fn into_variants_overwrite_stale_buffers() {
    let mut rng = Rng::new(104);
    let (m, k, n) = (33usize, 29usize, 41usize);
    let a = rng.normal_vec(m * k, 0.5);
    let b = rng.normal_vec(k * n, 0.5);
    let want = linalg::naive_matmul(&a, &b, m, k, n);

    let mut out = vec![123.0f32; m * n];
    linalg::gemm_nn_into(None, &mut out, &a, &b, m, k, n);
    assert_close(&out, &want, 1e-3, "nn_into");

    let at = linalg::transpose(&a, m, k); // (k, m)
    out.fill(-55.0);
    linalg::gemm_tn_into(None, &mut out, &at, &b, k, m, n);
    assert_close(&out, &want, 1e-3, "tn_into");

    let bt = linalg::transpose(&b, k, n); // (n, k)
    out.fill(9e9);
    linalg::gemm_nt_into(None, &mut out, &a, &bt, m, k, n);
    assert_close(&out, &want, 1e-3, "nt_into");
}

/// The acceptance-criterion determinism property: bit-identical results
/// for 1/2/8 workers (and serial), across all three transpose variants,
/// on a matmul large enough to cross the parallel-dispatch threshold.
#[test]
fn pool_results_bit_identical_for_1_2_8_workers() {
    let mut rng = Rng::new(105);
    let (m, k, n) = (139usize, 128usize, 131usize);
    let a = rng.normal_vec(m * k, 0.5);
    let b = rng.normal_vec(k * n, 0.5);
    let a_t = rng.normal_vec(k * m, 0.5); // (k, m) operand for TN
    let b_t = rng.normal_vec(n * k, 0.5); // (n, k) operand for NT

    let nn = linalg::gemm_nn(None, &a, &b, m, k, n);
    let tn = linalg::gemm_tn(None, &a_t, &b, k, m, n);
    let nt = linalg::gemm_nt(None, &a, &b_t, m, k, n);
    for workers in [1usize, 2, 8] {
        let pool = ThreadPool::new(workers);
        assert_eq!(nn, linalg::gemm_nn(Some(&pool), &a, &b, m, k, n), "nn w={workers}");
        assert_eq!(tn, linalg::gemm_tn(Some(&pool), &a_t, &b, k, m, n), "tn w={workers}");
        assert_eq!(nt, linalg::gemm_nt(Some(&pool), &a, &b_t, m, k, n), "nt w={workers}");
    }
}

/// A large parallel GEMM must also be bit-stable across *repeated* runs
/// on the same pool (no scheduling-order dependence).
#[test]
fn pool_results_stable_across_runs() {
    let mut rng = Rng::new(106);
    let (m, k, n) = (256usize, 256usize, 256usize);
    let a = rng.normal_vec(m * k, 0.1);
    let b = rng.normal_vec(k * n, 0.1);
    let pool = ThreadPool::new(4);
    let first = linalg::gemm_nn(Some(&pool), &a, &b, m, k, n);
    for _ in 0..3 {
        assert_eq!(first, linalg::gemm_nn(Some(&pool), &a, &b, m, k, n));
    }
    assert_eq!(first, linalg::gemm_nn(None, &a, &b, m, k, n), "parallel != serial");
}

#[test]
fn transpose_and_blocks_match_reference() {
    let mut rng = Rng::new(107);
    for &(m, n) in &[(1usize, 1usize), (2, 7), (13, 5), (64, 33), (100, 100)] {
        let x = rng.normal_vec(m * n, 1.0);
        let t = linalg::transpose(&x, m, n);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(t[j * m + i], x[i * n + j], "transpose {m}x{n} at ({i},{j})");
            }
        }
        assert_eq!(linalg::transpose(&t, n, m), x, "roundtrip {m}x{n}");
    }
    // Block transpose == the mode-2 unfolding semantics.
    let (d0, d1, kk) = (4usize, 3usize, 5usize);
    let x = rng.normal_vec(d0 * d1 * kk, 1.0);
    let u = linalg::transpose_blocks(&x, d0, d1, kk);
    for a in 0..d0 {
        for b in 0..d1 {
            for k in 0..kk {
                assert_eq!(u[b * (d0 * kk) + a * kk + k], x[(a * d1 + b) * kk + k]);
            }
        }
    }
}

#[test]
fn zero_sized_operands_are_safe() {
    // k = 0: the product is all zeros; stale buffers still cleared.
    let mut out = vec![3.0f32; 6];
    linalg::gemm_nn_into(None, &mut out, &[], &[], 2, 0, 3);
    assert_eq!(out, vec![0.0; 6]);
}
