//! Property suite for the shared kernel layer (`tensor::linalg`): the
//! blocked/SIMD NN/TN/NT GEMMs must agree with the naive triple-loop
//! oracle across adversarial shapes (1x1, primes, m >> n, n >> m), the
//! `*_into` variants must fully overwrite stale buffers, and the
//! pool-parallel path must be bit-identical to serial for any worker
//! count — the kernel-layer extension of PR 1's thread-count-invariance
//! contract.
//!
//! The ISA-dispatch matrix below additionally pins every public GEMM
//! entry point (f32/bf16/int8 × NN/TN/NT × `*_into`) to be bit-identical
//! between the detected SIMD kernel set and the forced scalar fallback
//! (`COAP_FORCE_SCALAR=1` / `linalg::force_scalar`), the low-precision
//! variants to the dequantize-then-f32-GEMM oracle, the level-1 kernels
//! (dot/axpy/rot) to scalar on all small/misaligned lengths, and the
//! fused low-precision packing to its no-full-materialization claim via
//! the pack-scratch byte counters.

use coap::rng::Rng;
use coap::tensor::{bf16, linalg, quant};
use coap::util::threadpool::ThreadPool;
use std::sync::Mutex;

/// Serializes tests that flip the process-global scalar-fallback pin.
/// (Other tests in this binary may observe the scalar set while one of
/// these runs — harmless, since scalar/SIMD bit-identity is exactly the
/// contract under test.)
static ISA_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` twice under the ISA lock — once on the detected kernel set,
/// once with the scalar fallback pinned — restoring the previous pin,
/// and return both results for a bit-identity comparison.
fn dispatched_and_scalar<R>(f: impl Fn() -> R) -> (R, R) {
    let _g = ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = linalg::scalar_forced();
    linalg::force_scalar(false);
    let dispatched = f();
    linalg::force_scalar(true);
    let scalar = f();
    linalg::force_scalar(prev);
    (dispatched, scalar)
}

/// |got - want| <= tol elementwise (FP-order drift between the blocked
/// core and the oracle is ~1e-5 at these depths; 1e-3 has wide margin).
fn assert_close(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!((g - w).abs() <= tol, "{ctx}: idx {i}: got {g}, want {w}");
    }
}

const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 7, 1),
    (3, 1, 5),
    (2, 2, 2),
    (5, 3, 2),
    (7, 13, 11),
    (17, 17, 17),
    (31, 63, 33),
    (64, 64, 64),
    (65, 129, 67),
    (128, 40, 96),
    (200, 3, 1),    // m >> n
    (1, 5, 190),    // n >> m
    (150, 257, 5),  // k spanning two KC blocks
    (3, 300, 3),    // deep and skinny
];

#[test]
fn gemm_nn_matches_naive_oracle() {
    let mut rng = Rng::new(101);
    for &(m, k, n) in SHAPES {
        let a = rng.normal_vec(m * k, 0.5);
        let b = rng.normal_vec(k * n, 0.5);
        let want = linalg::naive_matmul(&a, &b, m, k, n);
        let got = linalg::gemm_nn(None, &a, &b, m, k, n);
        assert_close(&got, &want, 1e-3, &format!("nn {m}x{k}x{n}"));
    }
}

#[test]
fn gemm_tn_matches_transposed_oracle() {
    let mut rng = Rng::new(102);
    for &(m, k, n) in SHAPES {
        // a stored (k, m): gemm_tn computes aᵀ·b = (m, k)·(k, n).
        let a = rng.normal_vec(k * m, 0.5);
        let b = rng.normal_vec(k * n, 0.5);
        let at = linalg::transpose(&a, k, m);
        let want = linalg::naive_matmul(&at, &b, m, k, n);
        let got = linalg::gemm_tn(None, &a, &b, k, m, n);
        assert_close(&got, &want, 1e-3, &format!("tn {m}x{k}x{n}"));
    }
}

#[test]
fn gemm_nt_matches_transposed_oracle() {
    let mut rng = Rng::new(103);
    for &(m, k, n) in SHAPES {
        // b stored (n, k): gemm_nt computes a·bᵀ = (m, k)·(k, n).
        let a = rng.normal_vec(m * k, 0.5);
        let b = rng.normal_vec(n * k, 0.5);
        let bt = linalg::transpose(&b, n, k);
        let want = linalg::naive_matmul(&a, &bt, m, k, n);
        let got = linalg::gemm_nt(None, &a, &b, m, k, n);
        assert_close(&got, &want, 1e-3, &format!("nt {m}x{k}x{n}"));
    }
}

#[test]
fn into_variants_overwrite_stale_buffers() {
    let mut rng = Rng::new(104);
    let (m, k, n) = (33usize, 29usize, 41usize);
    let a = rng.normal_vec(m * k, 0.5);
    let b = rng.normal_vec(k * n, 0.5);
    let want = linalg::naive_matmul(&a, &b, m, k, n);

    let mut out = vec![123.0f32; m * n];
    linalg::gemm_nn_into(None, &mut out, &a, &b, m, k, n);
    assert_close(&out, &want, 1e-3, "nn_into");

    let at = linalg::transpose(&a, m, k); // (k, m)
    out.fill(-55.0);
    linalg::gemm_tn_into(None, &mut out, &at, &b, k, m, n);
    assert_close(&out, &want, 1e-3, "tn_into");

    let bt = linalg::transpose(&b, k, n); // (n, k)
    out.fill(9e9);
    linalg::gemm_nt_into(None, &mut out, &a, &bt, m, k, n);
    assert_close(&out, &want, 1e-3, "nt_into");
}

/// The acceptance-criterion determinism property: bit-identical results
/// for 1/2/8 workers (and serial), across all three transpose variants,
/// on a matmul large enough to cross the parallel-dispatch threshold.
#[test]
fn pool_results_bit_identical_for_1_2_8_workers() {
    let mut rng = Rng::new(105);
    let (m, k, n) = (139usize, 128usize, 131usize);
    let a = rng.normal_vec(m * k, 0.5);
    let b = rng.normal_vec(k * n, 0.5);
    let a_t = rng.normal_vec(k * m, 0.5); // (k, m) operand for TN
    let b_t = rng.normal_vec(n * k, 0.5); // (n, k) operand for NT

    let nn = linalg::gemm_nn(None, &a, &b, m, k, n);
    let tn = linalg::gemm_tn(None, &a_t, &b, k, m, n);
    let nt = linalg::gemm_nt(None, &a, &b_t, m, k, n);
    for workers in [1usize, 2, 8] {
        let pool = ThreadPool::new(workers);
        assert_eq!(nn, linalg::gemm_nn(Some(&pool), &a, &b, m, k, n), "nn w={workers}");
        assert_eq!(tn, linalg::gemm_tn(Some(&pool), &a_t, &b, k, m, n), "tn w={workers}");
        assert_eq!(nt, linalg::gemm_nt(Some(&pool), &a, &b_t, m, k, n), "nt w={workers}");
    }
}

/// A large parallel GEMM must also be bit-stable across *repeated* runs
/// on the same pool (no scheduling-order dependence).
#[test]
fn pool_results_stable_across_runs() {
    let mut rng = Rng::new(106);
    let (m, k, n) = (256usize, 256usize, 256usize);
    let a = rng.normal_vec(m * k, 0.1);
    let b = rng.normal_vec(k * n, 0.1);
    let pool = ThreadPool::new(4);
    let first = linalg::gemm_nn(Some(&pool), &a, &b, m, k, n);
    for _ in 0..3 {
        assert_eq!(first, linalg::gemm_nn(Some(&pool), &a, &b, m, k, n));
    }
    assert_eq!(first, linalg::gemm_nn(None, &a, &b, m, k, n), "parallel != serial");
}

#[test]
fn transpose_and_blocks_match_reference() {
    let mut rng = Rng::new(107);
    for &(m, n) in &[(1usize, 1usize), (2, 7), (13, 5), (64, 33), (100, 100)] {
        let x = rng.normal_vec(m * n, 1.0);
        let t = linalg::transpose(&x, m, n);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(t[j * m + i], x[i * n + j], "transpose {m}x{n} at ({i},{j})");
            }
        }
        assert_eq!(linalg::transpose(&t, n, m), x, "roundtrip {m}x{n}");
    }
    // Block transpose == the mode-2 unfolding semantics.
    let (d0, d1, kk) = (4usize, 3usize, 5usize);
    let x = rng.normal_vec(d0 * d1 * kk, 1.0);
    let u = linalg::transpose_blocks(&x, d0, d1, kk);
    for a in 0..d0 {
        for b in 0..d1 {
            for k in 0..kk {
                assert_eq!(u[b * (d0 * kk) + a * kk + k], x[(a * d1 + b) * kk + k]);
            }
        }
    }
}

#[test]
fn zero_sized_operands_are_safe() {
    // k = 0: the product is all zeros; stale buffers still cleared.
    let mut out = vec![3.0f32; 6];
    linalg::gemm_nn_into(None, &mut out, &[], &[], 2, 0, 3);
    assert_eq!(out, vec![0.0; 6]);
}

/// The ISA-dispatch acceptance matrix: every public GEMM entry point —
/// f32/bf16/int8 × NN/TN/NT, Vec and `*_into` forms — serial and on
/// 1/2/8-worker pools, is bit-identical between the detected kernel set
/// and the forced scalar fallback. One odd shape exercises the edge
/// tiles; one crosses the KC block and the parallel-dispatch threshold.
#[test]
fn all_entry_points_bit_identical_scalar_vs_dispatched() {
    let mut rng = Rng::new(201);
    let pools: Vec<ThreadPool> = [1usize, 2, 8].iter().map(|&w| ThreadPool::new(w)).collect();
    for &(m, k, n) in &[(5usize, 7usize, 9usize), (139, 128, 131)] {
        let a = rng.normal_vec(m * k, 0.5);
        let b = rng.normal_vec(k * n, 0.5);
        let a_t = linalg::transpose(&a, m, k); // (k, m) operand for TN
        let b_t = linalg::transpose(&b, k, n); // (n, k) operand for NT
        let mut b16 = vec![0u16; b.len()];
        bf16::encode(&b, &mut b16);
        let mut bt16 = vec![0u16; b_t.len()];
        bf16::encode(&b_t, &mut bt16);
        let bq = quant::quantize(&b);
        let btq = quant::quantize(&b_t);

        // All nine products at one dispatch state; each `*_into` form is
        // checked against its Vec form along the way.
        let run_all = |pool: Option<&ThreadPool>| -> Vec<Vec<f32>> {
            let mut outs: Vec<Vec<f32>> = Vec::with_capacity(9);
            {
                let mut push = |vecform: Vec<f32>, into: &dyn Fn(&mut [f32]), tag: &str| {
                    let mut out = vec![f32::NAN; vecform.len()];
                    into(&mut out);
                    assert_eq!(vecform, out, "{tag} {m}x{k}x{n}: _into drifted from Vec form");
                    outs.push(vecform);
                };
                push(
                    linalg::gemm_nn(pool, &a, &b, m, k, n),
                    &|o| linalg::gemm_nn_into(pool, o, &a, &b, m, k, n),
                    "nn f32",
                );
                push(
                    linalg::gemm_tn(pool, &a_t, &b, k, m, n),
                    &|o| linalg::gemm_tn_into(pool, o, &a_t, &b, k, m, n),
                    "tn f32",
                );
                push(
                    linalg::gemm_nt(pool, &a, &b_t, m, k, n),
                    &|o| linalg::gemm_nt_into(pool, o, &a, &b_t, m, k, n),
                    "nt f32",
                );
                push(
                    linalg::gemm_nn_bf16(pool, &a, &b16, m, k, n),
                    &|o| linalg::gemm_nn_bf16_into(pool, o, &a, &b16, m, k, n),
                    "nn bf16",
                );
                push(
                    linalg::gemm_tn_bf16(pool, &a_t, &b16, k, m, n),
                    &|o| linalg::gemm_tn_bf16_into(pool, o, &a_t, &b16, k, m, n),
                    "tn bf16",
                );
                push(
                    linalg::gemm_nt_bf16(pool, &a, &bt16, m, k, n),
                    &|o| linalg::gemm_nt_bf16_into(pool, o, &a, &bt16, m, k, n),
                    "nt bf16",
                );
                push(
                    linalg::gemm_nn_q8(pool, &a, &bq, m, k, n),
                    &|o| linalg::gemm_nn_q8_into(pool, o, &a, &bq, m, k, n),
                    "nn q8",
                );
                push(
                    linalg::gemm_tn_q8(pool, &a_t, &bq, k, m, n),
                    &|o| linalg::gemm_tn_q8_into(pool, o, &a_t, &bq, k, m, n),
                    "tn q8",
                );
                push(
                    linalg::gemm_nt_q8(pool, &a, &btq, m, k, n),
                    &|o| linalg::gemm_nt_q8_into(pool, o, &a, &btq, m, k, n),
                    "nt q8",
                );
            }
            outs
        };
        let (disp, scal) = dispatched_and_scalar(|| {
            let mut all = run_all(None);
            for p in &pools {
                all.extend(run_all(Some(p)));
            }
            all
        });
        assert_eq!(disp, scal, "{m}x{k}x{n}: dispatched vs forced-scalar");
    }
}

/// Low-precision entry points against the dequantize-then-f32-GEMM
/// oracle: decoding B up front and running the f32 path must give the
/// exact same bits as the fused packer that decodes panel-by-panel —
/// and both stay within tolerance of the naive triple loop.
#[test]
fn low_precision_entry_points_match_dequantize_oracle() {
    let mut rng = Rng::new(202);
    for &(m, k, n) in &[(5usize, 7usize, 9usize), (33, 70, 41), (65, 129, 67)] {
        let a = rng.normal_vec(m * k, 0.5);
        let b = rng.normal_vec(k * n, 0.5);
        let a_t = linalg::transpose(&a, m, k); // (k, m)
        let b_t = linalg::transpose(&b, k, n); // (n, k)

        let mut b16 = vec![0u16; b.len()];
        bf16::encode(&b, &mut b16);
        let mut bdec = vec![0.0f32; b.len()];
        bf16::decode(&b16, &mut bdec);
        let mut bt16 = vec![0u16; b_t.len()];
        bf16::encode(&b_t, &mut bt16);
        let mut btdec = vec![0.0f32; b_t.len()];
        bf16::decode(&bt16, &mut btdec);
        let ctx = format!("{m}x{k}x{n}");
        assert_eq!(
            linalg::gemm_nn_bf16(None, &a, &b16, m, k, n),
            linalg::gemm_nn(None, &a, &bdec, m, k, n),
            "nn bf16 {ctx}"
        );
        assert_eq!(
            linalg::gemm_tn_bf16(None, &a_t, &b16, k, m, n),
            linalg::gemm_tn(None, &a_t, &bdec, k, m, n),
            "tn bf16 {ctx}"
        );
        assert_eq!(
            linalg::gemm_nt_bf16(None, &a, &bt16, m, k, n),
            linalg::gemm_nt(None, &a, &btdec, m, k, n),
            "nt bf16 {ctx}"
        );
        assert_close(
            &linalg::gemm_nn_bf16(None, &a, &b16, m, k, n),
            &linalg::naive_matmul(&a, &bdec, m, k, n),
            1e-3,
            &format!("nn bf16 vs naive {ctx}"),
        );

        let bq = quant::quantize(&b);
        let bqdec = quant::dequantize_vec(&bq);
        let btq = quant::quantize(&b_t);
        let btqdec = quant::dequantize_vec(&btq);
        assert_eq!(
            linalg::gemm_nn_q8(None, &a, &bq, m, k, n),
            linalg::gemm_nn(None, &a, &bqdec, m, k, n),
            "nn q8 {ctx}"
        );
        assert_eq!(
            linalg::gemm_tn_q8(None, &a_t, &bq, k, m, n),
            linalg::gemm_tn(None, &a_t, &bqdec, k, m, n),
            "tn q8 {ctx}"
        );
        assert_eq!(
            linalg::gemm_nt_q8(None, &a, &btq, m, k, n),
            linalg::gemm_nt(None, &a, &btqdec, m, k, n),
            "nt q8 {ctx}"
        );
        assert_close(
            &linalg::gemm_nn_q8(None, &a, &bq, m, k, n),
            &linalg::naive_matmul(&a, &bqdec, m, k, n),
            1e-3,
            &format!("nn q8 vs naive {ctx}"),
        );
    }
}

/// The no-materialization acceptance claim: a quantized-B GEMM whose B
/// would be 2 MiB as f32 must never stage a full f32 copy of it — the
/// per-thread pack scratch only ever grows to panel size (KC×NC + MC×KC
/// floats ≈ 0.3 MiB). Each `#[test]` runs on its own thread, so this
/// thread's scratch high-water is exactly this GEMM's footprint.
#[test]
fn q8_gemm_packs_panels_without_full_materialization() {
    let mut rng = Rng::new(203);
    let (m, k, n) = (64usize, 512usize, 1024usize);
    let a = rng.normal_vec(m * k, 0.5);
    let bq = quant::quantize(&rng.normal_vec(k * n, 0.5));
    let out = linalg::gemm_nn_q8(None, &a, &bq, m, k, n);
    assert_eq!(out.len(), m * n);
    let cap = linalg::scratch_capacity_bytes();
    let b_bytes = k * n * 4;
    assert!(
        cap < b_bytes,
        "pack scratch ({cap} B) held a full f32 copy of B ({b_bytes} B)"
    );
    assert!(
        cap <= linalg::SCRATCH_RETAIN_BYTES,
        "retention cap violated: {cap} B"
    );
    assert!(linalg::peak_scratch_bytes() >= cap, "peak counter missed this thread");
}

/// Level-1 kernels (dot/axpy/rot): SIMD vs forced scalar must be
/// bit-identical on every length from empty through two SIMD widths
/// plus a tail, including misaligned (`&v[1..]`) slices.
#[test]
fn level1_kernels_bit_match_scalar_on_all_small_lengths() {
    let mut rng = Rng::new(204);
    let max = 19usize; // two 8-lane widths + tail
    let xs = rng.normal_vec(max + 1, 1.0);
    let ys = rng.normal_vec(max + 1, 1.0);
    for len in 0..=max {
        for offset in [0usize, 1] {
            let x = &xs[offset..offset + len];
            let y = &ys[offset..offset + len];
            let (d_disp, d_scal) = dispatched_and_scalar(|| linalg::dot(x, y));
            assert_eq!(
                d_disp.to_bits(),
                d_scal.to_bits(),
                "dot len={len} off={offset}: {d_disp} vs {d_scal}"
            );
            let (a_disp, a_scal) = dispatched_and_scalar(|| {
                let mut yv = y.to_vec();
                linalg::axpy(&mut yv, 0.37, x);
                yv
            });
            assert_eq!(a_disp, a_scal, "axpy len={len} off={offset}");
            let (r_disp, r_scal) = dispatched_and_scalar(|| {
                let (mut av, mut bv) = (x.to_vec(), y.to_vec());
                linalg::rot(&mut av, &mut bv, 0.8, 0.6);
                (av, bv)
            });
            assert_eq!(r_disp, r_scal, "rot len={len} off={offset}");
        }
    }
}
