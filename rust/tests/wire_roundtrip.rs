//! Property suite for the sweep worker wire (`coordinator::wire` +
//! `TrainConfig::to_json`/`from_json`): generator-driven
//! `TrainConfig`/`TrainEvent`/`TrainReport` values must survive
//! encode -> JSONL -> parse -> decode **exactly** — including NaN/±inf
//! float fields, -0.0, full-range u64 seeds, empty labels, very long
//! strings and labels full of quotes/newlines/control characters —
//! plus reject-tests for truncated and version-mismatched frames.
//!
//! Equality trick: the encoders are injective over the struct fields
//! and deterministic (BTreeMap key order, shortest-round-trip float
//! printing), so `encode(decode(encode(x))) == encode(x)` string
//! equality IS field-for-field equality — no PartialEq needed on types
//! that deliberately don't derive it.

use coap::config::{
    BackendKind, CheckpointPolicy, ConvFormat, MomentBase, OptKind, TrainConfig,
};
use coap::coordinator::wire::{self, Frame, Request, WireHello};
use coap::coordinator::{EvalPoint, RunSpec, TrainEvent, TrainReport};
use coap::rng::Rng;
use coap::tensor::Precision;
use coap::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

// ---------------------------------------------------------------------------
// Generators (seeded coap::rng — the suite is exactly reproducible)
// ---------------------------------------------------------------------------

fn gen_label(r: &mut Rng) -> String {
    match r.below(8) {
        0 => String::new(), // empty labels are legal rows
        1 => "x".repeat(8192), // max-length-ish stress
        2 => "quote\" back\\slash / fwd".into(),
        3 => "newline\n tab\t carriage\r nul\u{0} bell\u{7}".into(),
        4 => "unicode 😀 λ µ 中文 \u{fffd}".into(),
        5 => "\"]}{[,:".into(), // JSON metacharacters
        _ => format!("row-{}", r.below(100_000)),
    }
}

fn gen_f64(r: &mut Rng) -> f64 {
    match r.below(10) {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => f64::NEG_INFINITY,
        3 => 0.0,
        4 => -0.0,
        5 => 1e300,
        6 => (r.next_u64() % 1_000_000) as f64 / 997.0,
        7 => -((r.next_u64() % 1_000_000) as f64) * 1e12,
        _ => (r.next_u64() as i64 as f64) * 1e-18,
    }
}

fn gen_dur(r: &mut Rng) -> Duration {
    Duration::new(r.next_u64() % (1 << 40), (r.next_u64() % 1_000_000_000) as u32)
}

fn gen_eval(r: &mut Rng) -> EvalPoint {
    EvalPoint {
        step: r.below(100_000),
        loss: gen_f64(r),
        ppl: gen_f64(r),
        accuracy: if r.below(2) == 0 { Some(gen_f64(r)) } else { None },
        aux: if r.below(2) == 0 { Some(gen_f64(r)) } else { None },
    }
}

fn gen_config(r: &mut Rng) -> TrainConfig {
    const OPTS: [OptKind; 8] = [
        OptKind::AdamW,
        OptKind::Adafactor,
        OptKind::Coap,
        OptKind::CoapAdafactor,
        OptKind::Galore,
        OptKind::Flora,
        OptKind::Lora,
        OptKind::Relora,
    ];
    const PRECS: [Precision; 3] = [Precision::F32, Precision::Bf16, Precision::Int8];
    const FMTS: [ConvFormat; 3] = [ConvFormat::Tucker1, ConvFormat::Tucker2, ConvFormat::Full];
    let mut c = TrainConfig::default();
    c.model = gen_label(r);
    c.backend = if r.below(2) == 0 { BackendKind::Native } else { BackendKind::Xla };
    c.optimizer = OPTS[r.below(OPTS.len())];
    c.rank_ratio = gen_f64(r);
    c.t_update = r.below(1000);
    c.lambda = r.below(1000);
    c.lr = gen_f64(r) as f32;
    c.weight_decay = gen_f64(r) as f32;
    c.steps = r.below(1_000_000);
    c.seed = r.next_u64(); // full range: not representable as f64
    c.state_precision = PRECS[r.below(PRECS.len())];
    c.eval_every = r.below(10_000);
    c.eval_batches = r.below(64);
    c.log_every = r.below(1000);
    c.track_ceu = r.below(2) == 0;
    c.threads = r.below(128);
    c.threads_explicit = r.below(2) == 0;
    c.artifacts_dir = gen_label(r);
    c.ablation.use_recalib = r.below(2) == 0;
    c.ablation.use_pupdate = r.below(2) == 0;
    c.ablation.mse_term = r.below(2) == 0;
    c.ablation.cos_term = r.below(2) == 0;
    c.relora_merge_every = r.below(10_000);
    c.finetune = r.below(2) == 0;
    c.galore_interval = r.below(10_000);
    c.flora_interval = r.below(10_000);
    c.conv_format = FMTS[r.below(FMTS.len())];
    c.lowrank_base =
        if r.below(2) == 0 { MomentBase::Adam } else { MomentBase::Adafactor };
    c.activation_checkpoint = match r.below(4) {
        0 => CheckpointPolicy::None,
        1 => CheckpointPolicy::EveryK(1 + r.below(16)),
        2 => CheckpointPolicy::EveryK(1),
        _ => CheckpointPolicy::All,
    };
    c.activation_lowrank = r.below(2) == 0;
    c
}

fn gen_event(r: &mut Rng) -> TrainEvent {
    let run = r.below(64);
    let label: Arc<str> = Arc::from(gen_label(r));
    match r.below(8) {
        0 => TrainEvent::RunStarted {
            run,
            label,
            model: gen_label(r),
            steps: r.below(100_000),
        },
        1 => TrainEvent::Step {
            run,
            label,
            step: r.below(100_000),
            loss: gen_f64(r),
            ema: gen_f64(r),
            ms_per_step: gen_f64(r),
        },
        2 => TrainEvent::ProjRefresh {
            run,
            label,
            step: r.below(100_000),
            ms: gen_f64(r),
        },
        3 => TrainEvent::Eval { run, label, eval: gen_eval(r) },
        4 => TrainEvent::RunFinished {
            run,
            label,
            steps: r.below(100_000),
            final_train_loss: gen_f64(r),
            wall_s: gen_f64(r),
        },
        5 => TrainEvent::RunFailed {
            run,
            label,
            step: r.below(100_000),
            error: gen_label(r),
        },
        6 => TrainEvent::RowDispatched {
            run,
            label,
            peer: gen_label(r),
            attempt: 1 + r.below(4),
        },
        _ => TrainEvent::RowRequeued {
            run,
            label,
            peer: gen_label(r),
            attempt: 1 + r.below(4),
            error: gen_label(r),
        },
    }
}

fn gen_report(r: &mut Rng) -> TrainReport {
    let curve = |r: &mut Rng| -> Vec<(usize, f64)> {
        (0..r.below(20)).map(|_| (r.below(100_000), gen_f64(r))).collect()
    };
    TrainReport {
        label: gen_label(r),
        model: gen_label(r),
        steps: r.below(1_000_000),
        final_train_loss: gen_f64(r),
        final_eval: gen_eval(r),
        wall: gen_dur(r),
        fwdbwd_time: gen_dur(r),
        opt_step_time: gen_dur(r),
        proj_time: gen_dur(r),
        optimizer_bytes: r.below(1 << 40),
        opt_transient_bytes: r.below(1 << 30),
        param_bytes: r.below(1 << 40),
        activation_peak_bytes: r.below(1 << 40),
        activation_analytic_bytes: r.below(1 << 40),
        ceu_total: gen_f64(r),
        train_losses: curve(r),
        ceu_curve: curve(r),
        evals: (0..r.below(6)).map(|_| gen_eval(r)).collect(),
    }
}

// ---------------------------------------------------------------------------
// Round trips (~1k generated cases)
// ---------------------------------------------------------------------------

#[test]
fn prop_config_wire_roundtrips_exactly() {
    let mut r = Rng::new(0xC0AF_0001);
    for case in 0..400 {
        let cfg = gen_config(&mut r);
        let wire_text = cfg.to_json().to_string();
        let parsed = Json::parse(&wire_text)
            .unwrap_or_else(|e| panic!("case {case}: unparseable {wire_text}: {e}"));
        let back = TrainConfig::from_json(&parsed)
            .unwrap_or_else(|e| panic!("case {case}: undecodable {wire_text}: {e:#}"));
        assert_eq!(back.to_json().to_string(), wire_text, "case {case}");
        // Spot-check the two encodings with sharp edges.
        assert_eq!(back.seed, cfg.seed, "case {case}");
        assert_eq!(back.lr.to_bits(), cfg.lr.to_bits(), "case {case}");
    }
}

#[test]
fn prop_event_frames_roundtrip_exactly() {
    let mut r = Rng::new(0xC0AF_0002);
    for case in 0..400 {
        let ev = gen_event(&mut r);
        let line = wire::encode_event(&ev);
        assert!(!line.contains('\n'), "case {case}: frame spans lines: {line}");
        match wire::decode_frame(&line) {
            Ok(Frame::Event(back)) => {
                assert_eq!(wire::encode_event(&back), line, "case {case}")
            }
            other => panic!(
                "case {case}: not an event frame ({}): {line}",
                match other {
                    Ok(_) => "wrong kind".to_string(),
                    Err(e) => format!("{e:#}"),
                }
            ),
        }
    }
}

#[test]
fn prop_report_and_spec_frames_roundtrip_exactly() {
    let mut r = Rng::new(0xC0AF_0003);
    for case in 0..200 {
        let rep = gen_report(&mut r);
        let line = wire::encode_report(&rep);
        assert!(!line.contains('\n'), "case {case}: frame spans lines");
        match wire::decode_frame(&line) {
            Ok(Frame::Report(back)) => {
                assert_eq!(wire::encode_report(&back), line, "case {case}");
                assert_eq!(back.wall, rep.wall, "case {case}");
            }
            _ => panic!("case {case}: not a report frame: {line}"),
        }

        let spec = RunSpec { label: gen_label(&mut r), cfg: gen_config(&mut r) };
        let index = r.below(4096);
        let (bi, bspec) = wire::decode_spec(&wire::encode_spec(index, &spec))
            .unwrap_or_else(|e| panic!("case {case}: spec undecodable: {e:#}"));
        assert_eq!(bi, index, "case {case}");
        assert_eq!(bspec.label, spec.label, "case {case}");
        assert_eq!(
            bspec.cfg.to_json().to_string(),
            spec.cfg.to_json().to_string(),
            "case {case}"
        );
    }
}

// ---------------------------------------------------------------------------
// Reject tests: truncation, version skew, cross-kind confusion
// ---------------------------------------------------------------------------

/// Every strict prefix of a frame must decode to Err — never Ok, never
/// a panic (a killed child truncates its last line exactly like this).
#[test]
fn truncated_frames_are_rejected() {
    let mut r = Rng::new(0xC0AF_0004);
    let lines = [
        wire::encode_event(&gen_event(&mut r)),
        wire::encode_report(&gen_report(&mut r)),
        wire::encode_error("boom at step 3"),
    ];
    for line in &lines {
        for cut in 0..line.len() {
            if !line.is_char_boundary(cut) {
                continue;
            }
            assert!(
                wire::decode_frame(&line[..cut]).is_err(),
                "prefix of len {cut} decoded: {}",
                &line[..cut]
            );
        }
    }
    let spec_line = wire::encode_spec(0, &RunSpec::new("r", TrainConfig::default()));
    for cut in 0..spec_line.len() {
        if !spec_line.is_char_boundary(cut) {
            continue;
        }
        assert!(wire::decode_spec(&spec_line[..cut]).is_err(), "cut {cut}");
    }
}

#[test]
fn version_mismatched_frames_are_rejected() {
    let ev = TrainEvent::Step {
        run: 0,
        label: "r".into(),
        step: 1,
        loss: 1.0,
        ema: 1.0,
        ms_per_step: 1.0,
    };
    let good = wire::encode_event(&ev);
    assert!(wire::decode_frame(&good).is_ok());
    // Backwards compatibility: v1 (pre-remote) and v2 (pre-serve)
    // frames still decode under the v3 envelope check.
    for old in ["1", "2"] {
        let prior = good.replacen("\"v\":3", &format!("\"v\":{old}"), 1);
        assert_ne!(prior, good, "encoder no longer stamps v3");
        assert!(
            wire::decode_frame(&prior).is_ok(),
            "v{old} frames must still decode"
        );
    }
    for v in ["0", "4", "999", "\"3\"", "null"] {
        let skewed = good.replacen("\"v\":3", &format!("\"v\":{v}"), 1);
        assert_ne!(skewed, good, "replacement failed for v={v}");
        let err = wire::decode_frame(&skewed).unwrap_err();
        let msg = format!("{err:#}");
        // A number that isn't WIRE_VERSION names the mismatch; a
        // non-number fails the envelope type check.
        assert!(
            msg.contains("version mismatch") || msg.contains("'v'"),
            "v={v}: {msg}"
        );
    }
}

#[test]
fn cross_kind_frames_are_rejected() {
    let spec_line = wire::encode_spec(3, &RunSpec::new("r", TrainConfig::default()));
    // A spec frame is parent->child only.
    assert!(wire::decode_frame(&spec_line).is_err());
    // Child->parent frames are not specs.
    let err_line = wire::encode_error("x");
    assert!(wire::decode_spec(&err_line).is_err());
    // Unknown kinds and non-object lines fail.
    assert!(wire::decode_frame("{\"v\":1,\"frame\":\"telemetry\"}").is_err());
    assert!(wire::decode_frame("[1,2,3]").is_err());
    assert!(wire::decode_frame("").is_err());
}

/// The v2 control frames (heartbeat, hello, shutdown) and the
/// coordinator->peer `Request` envelope roundtrip exactly.
#[test]
fn v2_control_frames_roundtrip_exactly() {
    // Seq/proto ride plain JSON numbers (exact for integers < 2^53 —
    // they are counters, not seeds).
    for seq in [0u64, 1, 7, (1 << 52) + 3] {
        match wire::decode_frame(&wire::encode_heartbeat(seq)) {
            Ok(Frame::Heartbeat { seq: back }) => assert_eq!(back, seq),
            _ => panic!("heartbeat seq={seq} did not roundtrip"),
        }
    }

    let mut r = Rng::new(0xC0AF_0005);
    for case in 0..100 {
        let hello = WireHello {
            proto: r.next_u64() >> 12,
            peer: gen_label(&mut r),
            backends: (0..r.below(4)).map(|_| gen_label(&mut r)).collect(),
        };
        let line = wire::encode_hello(&hello);
        assert!(!line.contains('\n'), "case {case}: frame spans lines");
        match wire::decode_frame(&line) {
            Ok(Frame::Hello(back)) => assert_eq!(back, hello, "case {case}"),
            _ => panic!("case {case}: hello did not roundtrip: {line}"),
        }
    }

    // Requests: a spec frame decodes as Request::Spec, shutdown as
    // Request::Shutdown, and child->parent frames are not requests.
    let spec = RunSpec::new("req-row", TrainConfig::default());
    match wire::decode_request(&wire::encode_spec(11, &spec)) {
        Ok(Request::Spec(index, back)) => {
            assert_eq!(index, 11);
            assert_eq!(back.label, "req-row");
        }
        _ => panic!("spec frame is not a Spec request"),
    }
    assert!(matches!(wire::decode_request(&wire::encode_shutdown()), Ok(Request::Shutdown)));
    assert!(wire::decode_request(&wire::encode_heartbeat(0)).is_err());
    assert!(wire::decode_request(&wire::encode_error("x")).is_err());
}
