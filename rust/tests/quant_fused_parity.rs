//! Acceptance suite for the fused quantized-state path: the block-
//! streaming `exec_with_state` implementation must be **bit-identical**
//! to the pre-refactor round trip (dequantize-all → slice kernel →
//! requantize-all) for every projection policy × slot kind × storage
//! precision, and invariant under the per-slot worker fan-out
//! (`--threads 1/2/8`).
//!
//! The round-trip reference is `Backend::exec_with_state_roundtrip` — a
//! provided trait method no engine overrides — exposed as a full
//! backend via the [`RoundTrip`] adapter so entire training runs can be
//! replayed under the old semantics.

use coap::config::{ConvFormat, MomentBase, OptKind, TrainConfig};
use coap::coordinator::Trainer;
use coap::optim::StateBuf;
use coap::runtime::{names, Backend, ExperimentInfo, ModelInfo, NativeBackend};
use coap::tensor::state::StateView;
use coap::tensor::{Precision, Tensor};
use std::sync::Arc;

/// Backend adapter that pins the pre-fusion semantics: every
/// `exec_with_state` call takes the materialize → exec → re-store path.
struct RoundTrip(NativeBackend);

impl Backend for RoundTrip {
    fn label(&self) -> &'static str {
        "native-roundtrip"
    }

    fn exec(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.0.exec(name, inputs)
    }

    fn exec_with_state(
        &self,
        name: &str,
        inputs: &[&Tensor],
        states: &mut [StateView],
    ) -> anyhow::Result<Vec<Tensor>> {
        self.0.exec_with_state_roundtrip(name, inputs, states)
    }

    fn model(&self, name: &str) -> anyhow::Result<ModelInfo> {
        self.0.model(name)
    }

    fn model_names(&self) -> Vec<String> {
        self.0.model_names()
    }

    fn has_graph(&self, name: &str) -> bool {
        self.0.has_graph(name)
    }

    fn experiments(&self) -> Vec<ExperimentInfo> {
        self.0.experiments()
    }

    fn total_execs(&self) -> u64 {
        self.0.total_execs()
    }
}

fn cfg(
    model: &str,
    opt: OptKind,
    base: MomentBase,
    fmt: ConvFormat,
    prec: Precision,
    threads: usize,
) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.model = model.into();
    c.optimizer = opt;
    c.lowrank_base = base;
    c.conv_format = fmt;
    c.state_precision = prec;
    c.threads = threads;
    c.steps = 6;
    c.t_update = 2;
    c.lambda = 2;
    c.lr = 2e-3;
    c.eval_every = 0;
    c.log_every = 0;
    c
}

/// Run a full training loop and return every parameter as raw f32 bits.
fn run_bits(c: TrainConfig, rt: Arc<dyn Backend>) -> Vec<Vec<u32>> {
    let mut tr = Trainer::builder(c).backend(rt).quiet().build().unwrap();
    tr.run().unwrap();
    tr.params()
        .iter()
        .map(|t| t.f32s().iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// The acceptance matrix: fused runs (any worker count) must equal the
/// single-threaded round-trip replay bit-for-bit.
fn assert_parity(model: &str, opt: OptKind, base: MomentBase, fmt: ConvFormat, prec: Precision) {
    let reference = run_bits(
        cfg(model, opt, base, fmt, prec, 1),
        Arc::new(RoundTrip(NativeBackend::new())),
    );
    for threads in [1usize, 2, 8] {
        let fused = run_bits(
            cfg(model, opt, base, fmt, prec, threads),
            Arc::new(NativeBackend::new()),
        );
        assert_eq!(
            reference, fused,
            "fused path drifted: {opt:?}/{base:?}/{model}/{fmt:?}/{prec:?} threads={threads}"
        );
    }
}

#[test]
fn coap_matrix_int8_parity() {
    assert_parity(
        "lm_micro",
        OptKind::Coap,
        MomentBase::Adam,
        ConvFormat::Tucker2,
        Precision::Int8,
    );
}

#[test]
fn galore_matrix_int8_parity() {
    assert_parity(
        "lm_micro",
        OptKind::Galore,
        MomentBase::Adam,
        ConvFormat::Tucker2,
        Precision::Int8,
    );
}

#[test]
fn flora_matrix_int8_parity() {
    assert_parity(
        "lm_micro",
        OptKind::Flora,
        MomentBase::Adam,
        ConvFormat::Tucker2,
        Precision::Int8,
    );
}

#[test]
fn coap_conv_tucker2_int8_parity() {
    assert_parity(
        "cnn_micro",
        OptKind::Coap,
        MomentBase::Adam,
        ConvFormat::Tucker2,
        Precision::Int8,
    );
}

#[test]
fn coap_conv_tucker1_int8_parity() {
    assert_parity(
        "cnn_micro",
        OptKind::Coap,
        MomentBase::Adam,
        ConvFormat::Tucker1,
        Precision::Int8,
    );
}

#[test]
fn coap_conv_full_tucker_int8_parity() {
    assert_parity(
        "cnn_micro",
        OptKind::Coap,
        MomentBase::Adam,
        ConvFormat::Full,
        Precision::Int8,
    );
}

#[test]
fn galore_conv_adafactor_int8_parity() {
    assert_parity(
        "cnn_micro",
        OptKind::Galore,
        MomentBase::Adafactor,
        ConvFormat::Tucker2,
        Precision::Int8,
    );
}

#[test]
fn flora_adafactor_matrix_int8_parity() {
    assert_parity(
        "lm_micro",
        OptKind::Flora,
        MomentBase::Adafactor,
        ConvFormat::Tucker2,
        Precision::Int8,
    );
}

#[test]
fn fullrank_adamw_int8_parity() {
    assert_parity(
        "lm_micro",
        OptKind::AdamW,
        MomentBase::Adam,
        ConvFormat::Tucker2,
        Precision::Int8,
    );
}

#[test]
fn fullrank_adafactor_int8_parity() {
    assert_parity(
        "cnn_micro",
        OptKind::Adafactor,
        MomentBase::Adam,
        ConvFormat::Tucker2,
        Precision::Int8,
    );
}

#[test]
fn coap_matrix_bf16_parity() {
    assert_parity(
        "lm_micro",
        OptKind::Coap,
        MomentBase::Adam,
        ConvFormat::Tucker2,
        Precision::Bf16,
    );
}

#[test]
fn coap_matrix_f32_parity() {
    assert_parity(
        "lm_micro",
        OptKind::Coap,
        MomentBase::Adam,
        ConvFormat::Tucker2,
        Precision::F32,
    );
}

/// Kernel-level degenerate inputs: all-zero blocks, a huge outlier, a
/// sub-floor value and a NaN-free tiny tail must round-trip identically
/// through the fused and reference paths (the `nearest_code` edge
/// policy is shared, so the quantized states must match byte-for-byte).
#[test]
fn degenerate_state_blocks_agree_bitwise() {
    let be = NativeBackend::new();
    let (m, n, r) = (40usize, 32usize, 4usize);
    let (mb, nb) = (m.max(n), m.min(n));
    let name = names::matrix_proj("coap_adam_step", m, n, r);
    let w = Tensor::from_f32(&[m, n], (0..m * n).map(|i| (i as f32).sin() * 0.1).collect());
    let g = Tensor::from_f32(&[m, n], (0..m * n).map(|i| (i as f32).cos() * 0.02).collect());
    let p = Tensor::from_f32(&[nb, r], (0..nb * r).map(|i| ((i % 7) as f32 - 3.0) * 0.1).collect());
    let mut mvals = vec![0.0f32; mb * r];
    let mut vvals = vec![1e-4f32; mb * r];
    for i in 0..mb * r {
        mvals[i] = match i % 5 {
            0 => 0.0,
            1 => 1e5,
            2 => 1e-9,
            3 => -2.5e-3,
            _ => 0.03,
        };
    }
    vvals[0] = 0.0;
    vvals[1] = 1e8;
    vvals[2] = 1e-12;
    let seed_m = Tensor::from_f32(&[mb, r], mvals);
    let seed_v = Tensor::from_f32(&[mb, r], vvals);
    let scalars = [
        Tensor::scalar_f32(0.9),
        Tensor::scalar_f32(0.999),
        Tensor::scalar_f32(0.01),
        Tensor::scalar_f32(0.1),
    ];
    let inputs = [
        &w,
        &g,
        &p,
        &scalars[0],
        &scalars[1],
        &scalars[2],
        &scalars[3],
    ];

    let mut m_fused = StateBuf::zeros(&[mb, r], Precision::Int8);
    let mut v_fused = StateBuf::zeros(&[mb, r], Precision::Int8);
    m_fused.store(&seed_m);
    v_fused.store(&seed_v);
    let mut m_ref = m_fused.clone();
    let mut v_ref = v_fused.clone();

    let mut fused_views = [m_fused.view(), v_fused.view()];
    let out_fused = be.exec_with_state(&name, &inputs, &mut fused_views).unwrap();
    drop(fused_views);
    let mut ref_views = [m_ref.view(), v_ref.view()];
    let out_ref = be
        .exec_with_state_roundtrip(&name, &inputs, &mut ref_views)
        .unwrap();
    drop(ref_views);

    assert_eq!(out_fused[0].f32s(), out_ref[0].f32s(), "w' drifted");
    assert_eq!(out_fused[1].scalar(), out_ref[1].scalar(), "ceu drifted");
    let codes = |b: &StateBuf| match b {
        StateBuf::Int8 { q, .. } => (q.data.clone(), q.scales.clone()),
        _ => unreachable!(),
    };
    assert_eq!(codes(&m_fused), codes(&m_ref), "m codes/scales drifted");
    assert_eq!(codes(&v_fused), codes(&v_ref), "v codes/scales drifted");
}
