//! Cross-layer validation: the compiled HLO executables must agree with
//! the pure-Rust reference implementations (which were themselves
//! validated against numpy on the Python side). Any drift between the
//! three implementations of the paper's math fails here.
//!
//! Requires the `xla` feature plus `make artifacts`; the hermetic
//! default build validates the native backend against the same oracles
//! in `native_vs_refimpl.rs` instead.
#![cfg(feature = "xla")]

use coap::config::default_artifacts_dir;
use coap::optim::refimpl;
use coap::rng::Rng;
use coap::runtime::{names, Backend, Runtime};
use coap::tensor::Tensor;

fn runtime() -> Runtime {
    Runtime::open(&default_artifacts_dir()).expect("make artifacts first")
}

fn randmat(rng: &mut Rng, m: usize, n: usize, scale: f32) -> Tensor {
    Tensor::from_f32(&[m, n], rng.normal_vec(m * n, scale))
}

#[test]
fn adam_step_hlo_matches_refimpl() {
    let rt = runtime();
    let mut rng = Rng::new(1);
    let (m, n) = (128usize, 128usize);
    let w = randmat(&mut rng, m, n, 0.1);
    let g = randmat(&mut rng, m, n, 0.02);
    let mom = randmat(&mut rng, m, n, 0.01);
    let vom = {
        let mut v = randmat(&mut rng, m, n, 0.001);
        for x in v.f32s_mut() {
            *x = x.abs();
        }
        v
    };
    let t = 9usize;
    let (lr, wd) = (0.01f32, 0.1f32);
    let out = rt
        .exec(
            &names::fullrank("adam_step", m, n),
            &[
                &w,
                &g,
                &mom,
                &vom,
                &Tensor::scalar_f32(0.9f32.powi(t as i32)),
                &Tensor::scalar_f32(0.999f32.powi(t as i32)),
                &Tensor::scalar_f32(lr),
                &Tensor::scalar_f32(wd),
            ],
        )
        .unwrap();

    let mut w2 = w.f32s().to_vec();
    let mut m2 = mom.f32s().to_vec();
    let mut v2 = vom.f32s().to_vec();
    let ceu = refimpl::adamw_step_flat(&mut w2, g.f32s(), &mut m2, &mut v2, t, lr, wd);
    let wref = Tensor::from_f32(&[m, n], w2);
    assert!(out[0].max_abs_diff(&wref) < 1e-5, "w mismatch");
    assert!(out[1].max_abs_diff(&Tensor::from_f32(&[m, n], m2)) < 1e-6);
    assert!(out[2].max_abs_diff(&Tensor::from_f32(&[m, n], v2)) < 1e-7);
    assert!(
        (out[3].scalar() as f64 - ceu).abs() / ceu < 1e-3,
        "ceu {} vs {}",
        out[3].scalar(),
        ceu
    );
}

#[test]
fn recalib_hlo_matches_refimpl_subspace() {
    let rt = runtime();
    let mut rng = Rng::new(2);
    let (m, n, r) = (512usize, 128usize, 32usize);
    // Low-rank-ish gradient so the top subspace is well defined.
    let a = randmat(&mut rng, m, r, 1.0);
    let b = randmat(&mut rng, r, n, 1.0);
    let mut g = a.matmul(&b);
    for v in g.f32s_mut() {
        *v = *v * 0.01 + 0.0005 * rng.normal();
    }
    let p0 = refimpl::mgs_qr(&randmat(&mut rng, n, r, 1.0));
    let hlo = rt
        .exec(&names::matrix_proj("recalib", m, n, r), &[&p0, &g])
        .unwrap();
    let oracle = refimpl::lowcost_recalib(&g, &p0, 8);
    // Column order/sign may differ; compare the projectors P P^T.
    let proj = |p: &Tensor| p.matmul(&p.transposed2d());
    let d = proj(&hlo[0]).max_abs_diff(&proj(&oracle));
    assert!(d < 5e-2, "projector mismatch {d}");
}

#[test]
fn galore_svd_hlo_matches_refimpl_subspace() {
    let rt = runtime();
    let mut rng = Rng::new(3);
    let (m, n, r) = (256usize, 256usize, 64usize);
    let a = randmat(&mut rng, m, r, 1.0);
    let b = randmat(&mut rng, r, n, 1.0);
    let mut g = a.matmul(&b);
    for v in g.f32s_mut() {
        *v = *v * 0.01 + 0.0002 * rng.normal();
    }
    let hlo = rt
        .exec(&names::matrix_proj("galore_svd", m, n, r), &[&g])
        .unwrap();
    let (oracle, _) = refimpl::svd_topk(&g, r, 8);
    let proj = |p: &Tensor| p.matmul(&p.transposed2d());
    let d = proj(&hlo[0]).max_abs_diff(&proj(&oracle));
    assert!(d < 5e-2, "projector mismatch {d}");
}

#[test]
fn pupdate_hlo_descends_the_eqn6_objective() {
    let rt = runtime();
    let mut rng = Rng::new(4);
    let (m, n, r) = (512usize, 128usize, 32usize);
    let g = randmat(&mut rng, m, n, 0.05);
    let p0 = refimpl::mgs_qr(&randmat(&mut rng, n, r, 1.0));
    let m_proj = g.matmul(&p0);
    let hlo = rt
        .exec(&names::matrix_proj("pupdate", m, n, r), &[&p0, &g, &m_proj])
        .unwrap();
    let before = refimpl::eqn6_objective(&p0, &g, &m_proj);
    let after = refimpl::eqn6_objective(&hlo[0], &g, &m_proj);
    assert!(after < before, "objective rose {before} -> {after}");
    // And matches the Rust oracle's trajectory closely.
    let oracle = refimpl::pupdate_sgd(&p0, &g, &m_proj, 2, 0.1);
    let d = hlo[0].max_abs_diff(&oracle);
    assert!(d < 1e-3, "pupdate drift {d}");
}

#[test]
fn coap_adam_step_hlo_matches_manual_projection() {
    let rt = runtime();
    let mut rng = Rng::new(5);
    let (m, n, r) = (128usize, 128usize, 32usize);
    let w = randmat(&mut rng, m, n, 0.1);
    let g = randmat(&mut rng, m, n, 0.02);
    let p = refimpl::mgs_qr(&randmat(&mut rng, n, r, 1.0));
    let mom = Tensor::zeros(&[m, r]);
    let vom = Tensor::zeros(&[m, r]);
    let lr = 0.02f32;
    let out = rt
        .exec(
            &names::matrix_proj("coap_adam_step", m, n, r),
            &[
                &w,
                &g,
                &mom,
                &vom,
                &p,
                &Tensor::scalar_f32(0.9),
                &Tensor::scalar_f32(0.999),
                &Tensor::scalar_f32(lr),
                &Tensor::scalar_f32(0.0),
            ],
        )
        .unwrap();
    // Manual: project, refimpl-Adam in low-rank space, restore.
    let gp = g.matmul(&p);
    let mut m2 = vec![0.0f32; m * r];
    let mut v2 = vec![0.0f32; m * r];
    let delta = refimpl::adam_update(&mut m2, &mut v2, gp.f32s(), 0.9, 0.999);
    let dw = Tensor::from_f32(&[m, r], delta).matmul(&p.transposed2d());
    let mut wref = w.f32s().to_vec();
    for (wi, di) in wref.iter_mut().zip(dw.f32s()) {
        *wi -= lr * di;
    }
    assert!(out[0].max_abs_diff(&Tensor::from_f32(&[m, n], wref)) < 1e-5);
    assert!(out[1].max_abs_diff(&Tensor::from_f32(&[m, r], m2)) < 1e-6);
}
