//! Acceptance suite for the steady-state step-path caches: the packed
//! projection panels (`linalg::PackedMat` via `refimpl::ProjPack`), the
//! native backend's interned plan table, and the per-thread step arena.
//!
//! Contracts pinned here:
//! - **Bit-identity.** Training with cached panels threaded through
//!   `Backend::exec_with_state_packed` equals the unpacked fused path
//!   bit-for-bit across projection policy × storage precision × worker
//!   count — including across every refresh boundary, where the panels
//!   must be invalidated and rebuilt from the new projections.
//! - **Counters.** On pure `Keep` steps nothing re-packs
//!   (`linalg::packed_builds` flat), nothing re-parses graph names
//!   (`NativeBackend::plan_builds` flat), and the arena stops missing
//!   (`arena::alloc_events` flat) once warm; a refresh step rebuilds the
//!   panels (`packed_builds` rises).
//!
//! The counter checks read process-global counters, so every test in
//! this file serializes on one mutex (other integration-test files run
//! as separate processes and cannot interfere).

use coap::config::{ConvFormat, MomentBase, OptKind, TrainConfig};
use coap::coordinator::Trainer;
use coap::model::ParamStore;
use coap::optim::lowrank::LowRank;
use coap::optim::Optimizer;
use coap::runtime::{Backend, ExperimentInfo, ModelInfo, NativeBackend};
use coap::tensor::linalg::MatRef;
use coap::tensor::state::StateView;
use coap::tensor::{arena, linalg, Precision, Tensor};
use std::sync::{Arc, Mutex, MutexGuard};

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    // A panicking test must not wedge the rest of the file.
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Backend adapter that drops the cached panels: `exec_with_state_packed`
/// is deliberately NOT overridden, so the trait default discards `pack`
/// and every step takes the unpacked (pack-from-`p`-each-call) fused
/// path. Everything else delegates to the real native backend.
struct NoPack(NativeBackend);

impl Backend for NoPack {
    fn label(&self) -> &'static str {
        "native-nopack"
    }

    fn exec(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.0.exec(name, inputs)
    }

    fn exec_with_state(
        &self,
        name: &str,
        inputs: &[&Tensor],
        states: &mut [StateView],
    ) -> anyhow::Result<Vec<Tensor>> {
        self.0.exec_with_state(name, inputs, states)
    }

    fn exec_pupdate(
        &self,
        name: &str,
        p: &Tensor,
        g2: &Tensor,
        moment: MatRef<'_>,
        mdims: (usize, usize),
    ) -> anyhow::Result<Vec<Tensor>> {
        self.0.exec_pupdate(name, p, g2, moment, mdims)
    }

    fn fuses_states(&self) -> bool {
        self.0.fuses_states()
    }

    fn model(&self, name: &str) -> anyhow::Result<ModelInfo> {
        self.0.model(name)
    }

    fn model_names(&self) -> Vec<String> {
        self.0.model_names()
    }

    fn has_graph(&self, name: &str) -> bool {
        self.0.has_graph(name)
    }

    fn experiments(&self) -> Vec<ExperimentInfo> {
        self.0.experiments()
    }

    fn total_execs(&self) -> u64 {
        self.0.total_execs()
    }
}

/// Six steps with `t_update = 2, λ = 2` crosses every refresh kind the
/// policy can emit (Recalib at t = 1 and 4, PUpdate at t = 2 and 6), so
/// a stale-panel bug anywhere in the invalidation rule shows up as a
/// parameter diff.
fn cfg(
    model: &str,
    opt: OptKind,
    base: MomentBase,
    fmt: ConvFormat,
    prec: Precision,
    threads: usize,
) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.model = model.into();
    c.optimizer = opt;
    c.lowrank_base = base;
    c.conv_format = fmt;
    c.state_precision = prec;
    c.threads = threads;
    c.steps = 6;
    c.t_update = 2;
    c.lambda = 2;
    c.lr = 2e-3;
    c.eval_every = 0;
    c.log_every = 0;
    c
}

fn run_bits(c: TrainConfig, rt: Arc<dyn Backend>) -> Vec<Vec<u32>> {
    let mut tr = Trainer::builder(c).backend(rt).quiet().build().unwrap();
    tr.run().unwrap();
    tr.params()
        .iter()
        .map(|t| t.f32s().iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// Packed runs (any worker count) must equal the unpacked reference
/// bit-for-bit — the panel cache may never change a single bit.
fn assert_packed_parity(model: &str, opt: OptKind, base: MomentBase, fmt: ConvFormat) {
    let _g = lock();
    for prec in [Precision::F32, Precision::Bf16, Precision::Int8] {
        let reference = run_bits(
            cfg(model, opt, base, fmt, prec, 1),
            Arc::new(NoPack(NativeBackend::new())),
        );
        for threads in [1usize, 2, 8] {
            let packed = run_bits(
                cfg(model, opt, base, fmt, prec, threads),
                Arc::new(NativeBackend::new()),
            );
            assert_eq!(
                reference, packed,
                "panel cache drifted: {opt:?}/{base:?}/{model}/{fmt:?}/{prec:?} \
                 threads={threads}"
            );
        }
    }
}

#[test]
fn coap_matrix_packed_parity_all_precisions() {
    assert_packed_parity("lm_micro", OptKind::Coap, MomentBase::Adam, ConvFormat::Tucker2);
}

#[test]
fn galore_matrix_packed_parity_all_precisions() {
    assert_packed_parity("lm_micro", OptKind::Galore, MomentBase::Adam, ConvFormat::Tucker2);
}

#[test]
fn flora_matrix_packed_parity_all_precisions() {
    assert_packed_parity("lm_micro", OptKind::Flora, MomentBase::Adam, ConvFormat::Tucker2);
}

#[test]
fn coap_conv_tucker2_packed_parity_all_precisions() {
    assert_packed_parity("cnn_micro", OptKind::Coap, MomentBase::Adam, ConvFormat::Tucker2);
}

#[test]
fn coap_conv_full_tucker_packed_parity_all_precisions() {
    assert_packed_parity("cnn_micro", OptKind::Coap, MomentBase::Adam, ConvFormat::Full);
}

#[test]
fn coap_conv_adafactor_packed_parity_all_precisions() {
    assert_packed_parity("cnn_micro", OptKind::Coap, MomentBase::Adafactor, ConvFormat::Tucker2);
}

/// Direct per-step driver: a `LowRank` on synthetic gradients, so each
/// test controls the exact step number `t` the schedule sees (the
/// trainer restarts `t` per `run()` call, which would re-trigger the
/// t = 1 refresh).
fn lowrank_rig(
    be: &NativeBackend,
    t_update: usize,
    lambda: usize,
) -> (LowRank, Vec<Tensor>, Vec<Tensor>) {
    let info = be.model("lm_micro").unwrap();
    let mut c = cfg(
        "lm_micro",
        OptKind::Coap,
        MomentBase::Adam,
        ConvFormat::Tucker2,
        Precision::F32,
        1,
    );
    c.t_update = t_update;
    c.lambda = lambda;
    let opt = LowRank::new(&c, &info).unwrap();
    let store = ParamStore::init(&info, 0, false);
    let grads: Vec<Tensor> = info
        .params
        .iter()
        .map(|p| {
            let vals: Vec<f32> = (0..p.numel()).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect();
            Tensor::from_f32(&p.shape, vals)
        })
        .collect();
    (opt, store.params, grads)
}

/// Pure-`Keep` steady state: after warmup, further steps build no packed
/// panels, compile no plans, and stop missing the arena.
#[test]
fn keep_steps_never_repack_reparse_or_allocate() {
    let _g = lock();
    let pack_bytes_baseline = linalg::pack_cache_bytes();
    let be = NativeBackend::new();
    // Only t = 1 refreshes; every later step is ProjAction::Keep.
    let (mut opt, mut params, grads) = lowrank_rig(&be, 1000, 1000);

    opt.step(1, 2e-3, &grads, &mut params, &be).unwrap(); // Recalib: panels built
    opt.step(2, 2e-3, &grads, &mut params, &be).unwrap(); // Keep
    let packs = linalg::packed_builds();
    let plans = be.plan_builds();
    assert!(packs > 0, "warmup never built packed panels");
    assert!(plans > 0, "plan cache never compiled anything");
    assert!(linalg::pack_cache_bytes() > pack_bytes_baseline, "no panels retained");
    assert!(opt.pack_cache_bytes() > 0, "optimizer reports no pack-cache bytes");

    // One more Keep step lets the arena freelists reach their fixed
    // point before the alloc counter is pinned.
    opt.step(3, 2e-3, &grads, &mut params, &be).unwrap();
    let allocs = arena::alloc_events();
    for t in 4..=8 {
        opt.step(t, 2e-3, &grads, &mut params, &be).unwrap();
    }
    assert_eq!(linalg::packed_builds(), packs, "Keep steps re-packed projection panels");
    assert_eq!(be.plan_builds(), plans, "steady-state steps re-parsed graph names");
    assert_eq!(arena::alloc_events(), allocs, "steady-state steps missed the step arena");

    // Dropping the optimizer frees every retained panel (Drop balance).
    drop(opt);
    assert_eq!(linalg::pack_cache_bytes(), pack_bytes_baseline, "pack-cache bytes leaked");
}

/// An Eqn-6/Eqn-7 refresh invalidates the cached panels: the next step
/// rebuilds them from the new projections, while the interned plans are
/// reused untouched.
#[test]
fn refresh_rebuilds_the_panel_cache() {
    let _g = lock();
    let be = NativeBackend::new();
    let (mut opt, mut params, grads) = lowrank_rig(&be, 2, 2);

    // t = 1 Recalib (initial build), t = 2 PUpdate (Eqn-6), t = 3 Keep.
    for t in 1..=3 {
        opt.step(t, 2e-3, &grads, &mut params, &be).unwrap();
    }
    let packs = linalg::packed_builds();
    let plans = be.plan_builds();
    assert!(packs > 0, "warmup never built panels");

    opt.step(4, 2e-3, &grads, &mut params, &be).unwrap(); // Recalib (Eqn-7)
    assert!(linalg::packed_builds() > packs, "refresh left stale packed panels in the cache");
    assert_eq!(be.plan_builds(), plans, "refresh re-parsed already-interned graph names");

    let packs = linalg::packed_builds();
    opt.step(5, 2e-3, &grads, &mut params, &be).unwrap(); // Keep again
    assert_eq!(linalg::packed_builds(), packs, "Keep step after refresh re-packed");
}
