//! Optimizer state-machine integration on the native backend: schedules,
//! ablation flags, conv Tucker-1/2/full paths, adafactor bases,
//! LoRA/ReLoRA, and the memory-accounting contracts the tables rely on.

use coap::config::{ConvFormat, MomentBase, OptKind, TrainConfig};
use coap::coordinator::Trainer;
use coap::runtime::{Backend, NativeBackend};
use std::sync::Arc;

fn backend() -> Arc<dyn Backend> {
    Arc::new(NativeBackend::new())
}

fn cfg(model: &str, opt: OptKind, steps: usize) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.model = model.into();
    c.optimizer = opt;
    c.steps = steps;
    c.lr = 2e-3;
    c.t_update = 4;
    c.lambda = 2;
    c.eval_every = 0;
    c.log_every = 0;
    c
}

fn run(c: TrainConfig, rt: &Arc<dyn Backend>) -> coap::coordinator::TrainReport {
    let mut tr = Trainer::builder(c).backend(Arc::clone(rt)).quiet().build().unwrap();
    tr.run().unwrap()
}

#[test]
fn conv_model_trains_under_every_lowrank_policy() {
    let rt = backend();
    for opt in [OptKind::Coap, OptKind::Galore, OptKind::Flora, OptKind::CoapAdafactor] {
        let rep = run(cfg("cnn_tiny", opt, 10), &rt);
        assert!(
            rep.final_train_loss < rep.train_losses[0].1,
            "{opt:?}: {} -> {}",
            rep.train_losses[0].1,
            rep.final_train_loss
        );
        assert!(rep.final_train_loss.is_finite());
    }
}

/// Acceptance matrix: every projection policy × both moment bases
/// completes a multi-step training loop on matrix (lm), Tucker-1 and
/// Tucker-2 conv slots, entirely on the native backend.
#[test]
fn policy_base_matrix_covers_all_slot_kinds() {
    let rt = backend();
    for policy in [OptKind::Coap, OptKind::Galore, OptKind::Flora] {
        for base in [MomentBase::Adam, MomentBase::Adafactor] {
            for (model, fmt) in [
                ("lm_micro", ConvFormat::Tucker2),
                ("cnn_micro", ConvFormat::Tucker1),
                ("cnn_micro", ConvFormat::Tucker2),
            ] {
                let mut c = cfg(model, policy, 9);
                c.lowrank_base = base;
                c.conv_format = fmt;
                c.t_update = 3;
                c.lambda = 2;
                let rep = run(c, &rt);
                assert!(
                    rep.final_train_loss.is_finite()
                        && rep.final_train_loss < rep.train_losses[0].1,
                    "{policy:?}/{base:?}/{model}/{fmt:?}: {} -> {}",
                    rep.train_losses[0].1,
                    rep.final_train_loss
                );
            }
        }
    }
}

#[test]
fn controlnet_model_reports_keypoint_proxy() {
    let rt = backend();
    let mut c = cfg("ctrl_micro", OptKind::CoapAdafactor, 8);
    c.eval_every = 8;
    c.eval_batches = 1;
    let rep = run(c, &rt);
    assert!(rep.final_eval.aux.is_some(), "mAP-proxy missing");
}

#[test]
fn adafactor_base_uses_less_memory_than_adam_base() {
    let rt = backend();
    let mut a = cfg("lm_tiny", OptKind::Coap, 4);
    a.track_ceu = false;
    let mut b = cfg("lm_tiny", OptKind::CoapAdafactor, 4);
    b.track_ceu = false;
    let ra = run(a, &rt);
    let rb = run(b, &rt);
    // Adafactor base: M + factored(R,C) < Adam's M + V.
    assert!(
        rb.optimizer_bytes < ra.optimizer_bytes,
        "adafactor {} !< adam {}",
        rb.optimizer_bytes,
        ra.optimizer_bytes
    );
}

#[test]
fn rank_ratio_controls_memory_monotonically() {
    let rt = backend();
    let mut bytes = Vec::new();
    for ratio in [2.0, 4.0, 8.0] {
        let mut c = cfg("lm_tiny", OptKind::Coap, 2);
        c.rank_ratio = ratio;
        bytes.push(run(c, &rt).optimizer_bytes);
    }
    assert!(bytes[0] > bytes[1] && bytes[1] > bytes[2], "{bytes:?}");
}

#[test]
fn ablation_flags_change_projection_work() {
    let rt = backend();
    // Disabling both Eqn-6 and Eqn-7 leaves P fixed at its random init:
    // proj time collapses to (almost) only the init cost.
    let mut on = cfg("lm_tiny", OptKind::Coap, 12);
    on.t_update = 2;
    on.lambda = 2;
    let mut off = on.clone();
    off.ablation.use_pupdate = false;
    off.ablation.use_recalib = false;
    let r_on = run(on, &rt);
    let r_off = run(off, &rt);
    assert!(
        r_off.proj_time < r_on.proj_time / 2,
        "ablated proj {:?} !<< full {:?}",
        r_off.proj_time,
        r_on.proj_time
    );
    // Still trains (fixed random projection is Flora-without-resampling).
    assert!(r_off.final_train_loss < r_off.train_losses[0].1);
}

#[test]
fn relora_merges_do_not_break_training() {
    let rt = backend();
    let mut c = cfg("lm_tiny", OptKind::Relora, 12);
    c.relora_merge_every = 4;
    let rep = run(c, &rt);
    assert!(rep.final_train_loss < rep.train_losses[0].1);
    assert!(rep.final_train_loss.is_finite());
}

#[test]
fn lora_uses_adapter_memory_not_full_moments() {
    let rt = backend();
    let lora = run(cfg("lm_tiny", OptKind::Lora, 4), &rt);
    let adam = run(cfg("lm_tiny", OptKind::AdamW, 4), &rt);
    assert!(lora.optimizer_bytes < adam.optimizer_bytes);
}

#[test]
fn tucker_formats_all_train_on_conv() {
    let rt = backend();
    for fmt in [ConvFormat::Tucker1, ConvFormat::Tucker2, ConvFormat::Full] {
        let mut c = cfg("cnn_tiny", OptKind::Coap, 8);
        c.conv_format = fmt;
        c.rank_ratio = 4.0;
        let rep = run(c, &rt);
        assert!(
            rep.final_train_loss.is_finite() && rep.final_train_loss < rep.train_losses[0].1,
            "{fmt:?} failed to train"
        );
    }
}

#[test]
fn galore_under_adafactor_base_trains() {
    let rt = backend();
    let mut c = cfg("lm_tiny", OptKind::Galore, 8);
    c.lowrank_base = MomentBase::Adafactor;
    let rep = run(c, &rt);
    assert!(rep.final_train_loss < rep.train_losses[0].1);
}

#[test]
fn galore_pays_more_projection_time_than_coap() {
    let rt = backend();
    // Same refresh cadence: GaLore full SVD vs COAP recalib+pupdate.
    let mut g = cfg("lm_tiny", OptKind::Galore, 10);
    g.t_update = 4;
    g.lambda = 2;
    g.galore_interval = 8;
    let mut c = cfg("lm_tiny", OptKind::Coap, 10);
    c.t_update = 4;
    c.lambda = 2;
    let rg = run(g, &rt);
    let rc = run(c, &rt);
    assert!(
        rg.proj_time > rc.proj_time * 2,
        "galore proj {:?} vs coap {:?} — the paper's cost gap vanished",
        rg.proj_time,
        rc.proj_time
    );
}
