//! Cross-engine validation for the hermetic build: every graph the
//! native backend executes must agree with a *manual composition* of the
//! refimpl oracles (projection → moment update → restore), including the
//! transpose normalization (GaLore side rule) and the Tucker-2 conv mode
//! products — the same contract `refimpl_vs_hlo.rs` pins on the XLA
//! engine, closing the native/HLO/oracle triangle.

use coap::optim::refimpl;
use coap::rng::Rng;
use coap::runtime::{names, Backend, NativeBackend};
use coap::tensor::Tensor;

fn randmat(rng: &mut Rng, dims: &[usize], scale: f32) -> Tensor {
    let n = dims.iter().product();
    Tensor::from_f32(dims, rng.normal_vec(n, scale))
}

fn s(x: f32) -> Tensor {
    Tensor::scalar_f32(x)
}

#[test]
fn native_adam_step_matches_refimpl() {
    let be = NativeBackend::new();
    let mut rng = Rng::new(1);
    let (m, n) = (48usize, 32usize);
    let w = randmat(&mut rng, &[m, n], 0.1);
    let g = randmat(&mut rng, &[m, n], 0.02);
    let mom = randmat(&mut rng, &[m, n], 0.01);
    let vom = {
        let mut v = randmat(&mut rng, &[m, n], 0.001);
        for x in v.f32s_mut() {
            *x = x.abs();
        }
        v
    };
    let t = 9usize;
    let (lr, wd) = (0.01f32, 0.1f32);
    let out = be
        .exec(
            &names::fullrank("adam_step", m, n),
            &[
                &w,
                &g,
                &mom,
                &vom,
                &s(0.9f32.powi(t as i32)),
                &s(0.999f32.powi(t as i32)),
                &s(lr),
                &s(wd),
            ],
        )
        .unwrap();
    let mut w2 = w.f32s().to_vec();
    let mut m2 = mom.f32s().to_vec();
    let mut v2 = vom.f32s().to_vec();
    let ceu = refimpl::adamw_step_flat(&mut w2, g.f32s(), &mut m2, &mut v2, t, lr, wd);
    assert!(out[0].max_abs_diff(&Tensor::from_f32(&[m, n], w2)) < 1e-6, "w mismatch");
    assert!(out[1].max_abs_diff(&Tensor::from_f32(&[m, n], m2)) < 1e-7);
    assert!(out[2].max_abs_diff(&Tensor::from_f32(&[m, n], v2)) < 1e-8);
    assert!((out[3].scalar() as f64 - ceu).abs() / ceu < 1e-3);
}

/// Acceptance criterion: native `coap_adam_step` matches the manual
/// refimpl composition to <= 1e-5, in both orientations of the GaLore
/// side rule (m >= n and m < n).
#[test]
fn native_coap_adam_step_matches_manual_projection_both_orientations() {
    let be = NativeBackend::new();
    for (seed, m, n, r) in [(5u64, 48usize, 32usize, 8usize), (6, 32, 48, 8)] {
        let mut rng = Rng::new(seed);
        let (mb, nb) = (m.max(n), m.min(n));
        let w = randmat(&mut rng, &[m, n], 0.1);
        let g = randmat(&mut rng, &[m, n], 0.02);
        let p = refimpl::mgs_qr(&randmat(&mut rng, &[nb, r], 1.0));
        let mom = randmat(&mut rng, &[mb, r], 0.01);
        let vom = {
            let mut v = randmat(&mut rng, &[mb, r], 0.001);
            for x in v.f32s_mut() {
                *x = x.abs();
            }
            v
        };
        let lr = 0.02f32;
        let out = be
            .exec(
                &names::matrix_proj("coap_adam_step", m, n, r),
                &[&w, &g, &mom, &vom, &p, &s(0.9), &s(0.999), &s(lr), &s(0.0)],
            )
            .unwrap();
        // Manual: normalize, project, refimpl-Adam in low-rank, restore.
        let gn = if m < n { g.transposed2d() } else { g.clone() };
        let gp = gn.matmul(&p); // (mb, r)
        let mut m2 = mom.f32s().to_vec();
        let mut v2 = vom.f32s().to_vec();
        let delta = refimpl::adam_update(&mut m2, &mut v2, gp.f32s(), 0.9, 0.999);
        let dw_n = Tensor::from_f32(&[mb, r], delta).matmul(&p.transposed2d());
        let dw = if m < n { dw_n.transposed2d() } else { dw_n };
        let mut wref = w.f32s().to_vec();
        for (wi, di) in wref.iter_mut().zip(dw.f32s()) {
            *wi -= lr * di;
        }
        assert!(
            out[0].max_abs_diff(&Tensor::from_f32(&[m, n], wref)) <= 1e-5,
            "w mismatch ({m}x{n})"
        );
        assert!(out[1].max_abs_diff(&Tensor::from_f32(&[mb, r], m2)) < 1e-6);
        assert!(out[2].max_abs_diff(&Tensor::from_f32(&[mb, r], v2)) < 1e-7);
        assert_eq!(out[1].dims(), &[mb, r]);
    }
}

#[test]
fn native_coap_adafactor_step_matches_manual_composition() {
    let be = NativeBackend::new();
    let mut rng = Rng::new(7);
    let (m, n, r) = (24usize, 40usize, 6usize); // transpose orientation
    let (mb, nb) = (m.max(n), m.min(n));
    let w = randmat(&mut rng, &[m, n], 0.1);
    let g = randmat(&mut rng, &[m, n], 0.05);
    let p = refimpl::mgs_qr(&randmat(&mut rng, &[nb, r], 1.0));
    let mom = randmat(&mut rng, &[mb, r], 0.01);
    let rf = Tensor::zeros(&[mb, 1]);
    let cf = Tensor::zeros(&[1, r]);
    let (t, lr) = (3usize, 0.01f32);
    let out = be
        .exec(
            &names::matrix_proj("coap_adafactor_step", m, n, r),
            &[&w, &g, &mom, &rf, &cf, &p, &s(t as f32), &s(lr)],
        )
        .unwrap();
    let gn = g.transposed2d();
    let gp = gn.matmul(&p);
    let mut m2 = mom.f32s().to_vec();
    let mut r2 = rf.f32s().to_vec();
    let mut c2 = cf.f32s().to_vec();
    let delta = refimpl::adafactor_delta(&mut m2, &mut r2, &mut c2, gp.f32s(), mb, r, t);
    let dw = Tensor::from_f32(&[mb, r], delta).matmul(&p.transposed2d()).transposed2d();
    let mut wref = w.f32s().to_vec();
    for (wi, di) in wref.iter_mut().zip(dw.f32s()) {
        *wi -= lr * di;
    }
    assert!(out[0].max_abs_diff(&Tensor::from_f32(&[m, n], wref)) <= 1e-5);
    assert!(out[1].max_abs_diff(&Tensor::from_f32(&[mb, r], m2)) < 1e-6);
    assert_eq!(out[2].dims(), &[mb, 1]);
    assert_eq!(out[3].dims(), &[1, r]);
}

#[test]
fn native_recalib_matches_refimpl_and_handles_transpose() {
    let be = NativeBackend::new();
    let mut rng = Rng::new(2);
    for (m, n, r) in [(96usize, 40usize, 8usize), (40, 96, 8)] {
        let nb = m.min(n);
        // Low-rank-ish gradient so the top subspace is well defined.
        let a = randmat(&mut rng, &[m, r], 1.0);
        let b = randmat(&mut rng, &[r, n], 1.0);
        let mut g = a.matmul(&b);
        for v in g.f32s_mut() {
            *v = *v * 0.01 + 0.0005 * rng.normal();
        }
        let p0 = refimpl::mgs_qr(&randmat(&mut rng, &[nb, r], 1.0));
        let out = be
            .exec(&names::matrix_proj("recalib", m, n, r), &[&p0, &g])
            .unwrap();
        let gn = if m < n { g.transposed2d() } else { g.clone() };
        let oracle = refimpl::lowcost_recalib(&gn, &p0, refimpl::SVD_SWEEPS);
        assert!(out[0].max_abs_diff(&oracle) < 1e-6, "recalib drift ({m}x{n})");
        assert_eq!(out[0].dims(), &[nb, r]);
    }
}

#[test]
fn native_pupdate_matches_refimpl_and_descends_eqn6() {
    let be = NativeBackend::new();
    let mut rng = Rng::new(4);
    let (m, n, r) = (96usize, 40usize, 8usize);
    let g = randmat(&mut rng, &[m, n], 0.05);
    let p0 = refimpl::mgs_qr(&randmat(&mut rng, &[n, r], 1.0));
    let m_proj = g.matmul(&p0);
    let out = be
        .exec(&names::matrix_proj("pupdate", m, n, r), &[&p0, &g, &m_proj])
        .unwrap();
    let oracle =
        refimpl::pupdate_sgd(&p0, &g, &m_proj, refimpl::PUPDATE_ITERS, refimpl::PUPDATE_LR);
    assert!(out[0].max_abs_diff(&oracle) < 1e-6);
    let before = refimpl::eqn6_objective(&p0, &g, &m_proj);
    let after = refimpl::eqn6_objective(&out[0], &g, &m_proj);
    assert!(after < before, "objective rose {before} -> {after}");
}

#[test]
fn native_galore_svd_matches_refimpl() {
    let be = NativeBackend::new();
    let mut rng = Rng::new(3);
    let (m, n, r) = (64usize, 48usize, 12usize);
    let a = randmat(&mut rng, &[m, r], 1.0);
    let b = randmat(&mut rng, &[r, n], 1.0);
    let g = a.matmul(&b);
    let out = be
        .exec(&names::matrix_proj("galore_svd", m, n, r), &[&g])
        .unwrap();
    let (oracle, _) = refimpl::svd_topk(&g, r, refimpl::SVD_SWEEPS);
    assert!(out[0].max_abs_diff(&oracle) < 1e-6);
}

/// Independent dense reference for the Tucker-2 conv Adam step: naive
/// einsum loops, no shared helpers with the production kernels.
#[test]
fn native_conv_step_matches_naive_einsum_reference() {
    let be = NativeBackend::new();
    let mut rng = Rng::new(8);
    let shape = [10usize, 6, 3, 3];
    let (o, i, k1, k2) = (shape[0], shape[1], shape[2], shape[3]);
    let (ro, ri) = (4usize, 3usize);
    let kk = k1 * k2;
    let w = randmat(&mut rng, &shape, 0.1);
    let g = randmat(&mut rng, &shape, 0.05);
    let po = refimpl::mgs_qr(&randmat(&mut rng, &[o, ro], 1.0));
    let pi = refimpl::mgs_qr(&randmat(&mut rng, &[i, ri], 1.0));
    let mom = Tensor::zeros(&[ro, ri, k1, k2]);
    let vom = Tensor::zeros(&[ro, ri, k1, k2]);
    let (lr, wd) = (0.02f32, 0.0f32);
    let name = names::conv("coap_adam_conv_step", &shape, ro, ri);
    let out = be
        .exec(
            &name,
            &[&w, &g, &mom, &vom, &po, &pi, &s(0.9), &s(0.999), &s(lr), &s(wd)],
        )
        .unwrap();

    // Naive: g_proj[r,si,k] = sum_{oo,ii} po[oo,r] pi[ii,si] g[oo,ii,k]
    let (gs, pos, pis) = (g.f32s(), po.f32s(), pi.f32s());
    let mut gproj = vec![0.0f32; ro * ri * kk];
    for r in 0..ro {
        for si in 0..ri {
            for k in 0..kk {
                let mut acc = 0.0f32;
                for oo in 0..o {
                    for ii in 0..i {
                        acc += pos[oo * ro + r] * pis[ii * ri + si] * gs[(oo * i + ii) * kk + k];
                    }
                }
                gproj[(r * ri + si) * kk + k] = acc;
            }
        }
    }
    let mut m2 = vec![0.0f32; ro * ri * kk];
    let mut v2 = vec![0.0f32; ro * ri * kk];
    let delta = refimpl::adam_update(&mut m2, &mut v2, &gproj, 0.9, 0.999);
    // dw[oo,ii,k] = sum_{r,si} po[oo,r] pi[ii,si] delta[r,si,k]
    let mut wref = w.f32s().to_vec();
    for oo in 0..o {
        for ii in 0..i {
            for k in 0..kk {
                let mut acc = 0.0f32;
                for r in 0..ro {
                    for si in 0..ri {
                        acc += pos[oo * ro + r] * pis[ii * ri + si] * delta[(r * ri + si) * kk + k];
                    }
                }
                wref[(oo * i + ii) * kk + k] -= lr * acc;
            }
        }
    }
    assert!(
        out[0].max_abs_diff(&Tensor::from_f32(&shape, wref)) <= 1e-5,
        "conv w mismatch"
    );
    assert!(out[1].max_abs_diff(&Tensor::from_f32(&[ro, ri, k1, k2], m2)) < 1e-6);
    assert_eq!(out[1].dims(), &[ro, ri, k1, k2]);
}

#[test]
fn native_conv_refreshes_return_wellformed_projections() {
    let be = NativeBackend::new();
    let mut rng = Rng::new(9);
    let shape = [12usize, 8, 3, 3];
    let (o, i) = (shape[0], shape[1]);
    let (ro, ri) = (4usize, 3usize);
    let g = randmat(&mut rng, &shape, 0.1);
    // SVD sides.
    let po = be
        .exec(&names::conv("conv_svd_o", &shape, ro, ri), &[&g])
        .unwrap();
    assert_eq!(po[0].dims(), &[o, ro]);
    let pi = be
        .exec(&names::conv("conv_svd_i", &shape, ro, ri), &[&g])
        .unwrap();
    assert_eq!(pi[0].dims(), &[i, ri]);
    // Recalib keeps shapes and returns ~unit columns.
    let p0 = refimpl::mgs_qr(&randmat(&mut rng, &[o, ro], 1.0));
    let rec = be
        .exec(&names::conv("conv_recalib_o", &shape, ro, ri), &[&p0, &g])
        .unwrap();
    assert_eq!(rec[0].dims(), &[o, ro]);
    for j in 0..ro {
        let col_norm: f32 = (0..o).map(|x| rec[0].f32s()[x * ro + j].powi(2)).sum::<f32>().sqrt();
        assert!((col_norm - 1.0).abs() < 0.05, "recalib col {j} norm {col_norm}");
    }
    // PUpdate runs and returns finite values with the right shape.
    let m_proj = randmat(&mut rng, &[ro, ri, 3, 3], 0.01);
    let pup = be
        .exec(
            &names::conv("conv_pupdate_o", &shape, ro, ri),
            &[&p0, &g, &m_proj, &pi[0]],
        )
        .unwrap();
    assert_eq!(pup[0].dims(), &[o, ro]);
    assert!(pup[0].f32s().iter().all(|v| v.is_finite()));
}

#[test]
fn native_exec_is_deterministic() {
    let be = NativeBackend::new();
    let mut rng = Rng::new(11);
    let (m, n, r) = (32usize, 20usize, 4usize);
    let g = randmat(&mut rng, &[m, n], 0.1);
    let a = be.exec(&names::matrix_proj("galore_svd", m, n, r), &[&g]).unwrap();
    let b = be.exec(&names::matrix_proj("galore_svd", m, n, r), &[&g]).unwrap();
    assert_eq!(a[0].f32s(), b[0].f32s());
}

#[test]
fn native_rejects_malformed_calls() {
    let be = NativeBackend::new();
    let g = Tensor::zeros(&[4, 4]);
    // Wrong input count.
    assert!(be.exec("galore_svd__4x4_r2", &[&g, &g]).is_err());
    // Unknown template.
    assert!(be.exec("warp_step__4x4", &[&g]).is_err());
    // Shape mismatch.
    let p = Tensor::zeros(&[3, 2]);
    assert!(be.exec("recalib__4x4_r2", &[&p, &g]).is_err());
    // Unknown model.
    assert!(be.exec("train_step__nope", &[]).is_err());
}
