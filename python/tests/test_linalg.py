"""Pure-jnp linear algebra vs numpy.linalg (which we cannot ship in the
HLO artifacts — LAPACK custom-calls don't resolve in xla_extension
0.5.1, see linalg.py)."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import linalg

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


@given(m=st.integers(8, 64), r=st.integers(1, 8), seed=st.integers(0, 2**31))
def test_mgs_qr_orthonormal_and_spans(m, r, seed):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.normal(size=(m, r)), jnp.float32)
    q = jax.jit(linalg.mgs_qr)(x)
    np.testing.assert_allclose(np.array(q.T @ q), np.eye(r), atol=2e-4)
    np.testing.assert_allclose(np.array(q @ (q.T @ x)), np.array(x), atol=2e-3)


@given(m=st.integers(10, 60), n=st.integers(4, 40), seed=st.integers(0, 2**31))
def test_jacobi_svd_matches_numpy(m, n, seed):
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(m, n)).astype(np.float32)
    k = min(4, n)
    p, sig = jax.jit(lambda g: linalg.svd_topk(g, k, sweeps=10))(jnp.array(g))
    _, s, vt = np.linalg.svd(g, full_matrices=False)
    np.testing.assert_allclose(np.array(sig), s[:k], rtol=5e-3, atol=1e-3)
    # subspace projectors agree (vectors may differ by sign/rotation)
    if k < n and (s[k - 1] - s[k]) > 0.1 * s[0]:  # well-separated only
        proj_ref = vt[:k].T @ vt[:k]
        proj_our = np.array(p) @ np.array(p).T
        np.testing.assert_allclose(proj_our, proj_ref, atol=5e-2)


def test_jacobi_handles_odd_columns():
    rng = np.random.default_rng(3)
    g = rng.normal(size=(20, 7)).astype(np.float32)
    y, v = linalg.onesided_jacobi(jnp.array(g), sweeps=10, compute_v=True)
    assert y.shape == (20, 7) and v.shape == (7, 7)
    # V orthogonal, Y = G V
    np.testing.assert_allclose(np.array(v.T @ v), np.eye(7), atol=1e-4)
    np.testing.assert_allclose(np.array(y), g @ np.array(v), atol=1e-3)
    # columns of Y pairwise orthogonal
    yty = np.array(y.T @ y)
    off = yty - np.diag(np.diag(yty))
    assert np.abs(off).max() < 1e-2 * np.abs(np.diag(yty)).max()


def test_recalib_beats_random_on_lowrank_gradients():
    rng = np.random.default_rng(4)
    a = rng.normal(size=(48, 4)).astype(np.float32)
    b = rng.normal(size=(4, 24)).astype(np.float32)
    g = a @ b + 0.05 * rng.normal(size=(48, 24)).astype(np.float32)
    p0, _ = np.linalg.qr(rng.normal(size=(24, 4)))
    p0 = p0.astype(np.float32)
    z = jax.jit(lambda g, p: linalg.lowcost_recalib(g, p))(jnp.array(g), jnp.array(p0))
    z = np.array(z)
    np.testing.assert_allclose(z.T @ z, np.eye(4), atol=2e-2)
    err = lambda P: np.linalg.norm(g @ P @ P.T - g)
    assert err(z) < 0.6 * err(p0)


def test_pupdate_descends_objective():
    rng = np.random.default_rng(5)
    g = jnp.array(rng.normal(size=(30, 16)), jnp.float32)
    q, _ = np.linalg.qr(rng.normal(size=(16, 4)))
    p0 = jnp.array(q, jnp.float32)
    m_proj = g @ p0 * 0.3

    def obj(p):
        ghat = g @ p @ p.T
        mse = jnp.mean((ghat - g) ** 2)
        mhat = m_proj @ p.T
        num = jnp.sum(mhat * g, axis=1)
        den = (jnp.linalg.norm(mhat, axis=1) * jnp.linalg.norm(g, axis=1)) + 1e-8
        return float(mse * (1 - jnp.mean(num / den)))

    p1 = jax.jit(lambda p, g, m: linalg.pupdate_sgd(p, g, m, iters=4, lr=0.1))(
        p0, g, m_proj)
    assert obj(np.array(p1)) < obj(p0)
    assert np.all(np.isfinite(np.array(p1)))
