"""L2 optimizer step graphs: semantics vs hand-rolled numpy, shape
contracts, and the projection side rule."""

import numpy as np
import jax
import jax.numpy as jnp
import functools

from compile import optim


def arr(rng, *shape, scale=1.0):
    return jnp.array(rng.normal(0, scale, shape), jnp.float32)


def np_adam(w, g, m, v, t, lr, wd=0.0):
    m = 0.9 * m + 0.1 * g
    v = 0.999 * v + 0.001 * g * g
    mh = m / (1 - 0.9**t)
    vh = v / (1 - 0.999**t)
    w2 = w - lr * (mh / (np.sqrt(vh) + 1e-8) + wd * w)
    return w2, m, v


def test_adam_step_matches_numpy():
    rng = np.random.default_rng(0)
    w, g = arr(rng, 6, 4, scale=0.1), arr(rng, 6, 4, scale=0.01)
    m, v = arr(rng, 6, 4, scale=0.01), jnp.abs(arr(rng, 6, 4, scale=0.001))
    t = 7
    out = jax.jit(optim.adam_step)(w, g, m, v, jnp.float32(0.9**t),
                                   jnp.float32(0.999**t), jnp.float32(0.01),
                                   jnp.float32(0.1))
    w2, m2, v2 = np_adam(np.array(w), np.array(g), np.array(m), np.array(v),
                         t, 0.01, 0.1)
    np.testing.assert_allclose(out[0], w2, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(out[1], m2, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(out[2], v2, rtol=1e-5, atol=1e-8)
    # CEU = ||w' - w||_1
    np.testing.assert_allclose(
        float(out[3]), np.abs(w2 - np.array(w)).sum(), rtol=1e-4)


def test_coap_adam_step_projected_semantics():
    """The projected step must equal: project G, Adam in low-rank space,
    restore through P^T."""
    rng = np.random.default_rng(1)
    m_, n_, r_ = 12, 8, 4
    w, g = arr(rng, m_, n_, scale=0.1), arr(rng, m_, n_, scale=0.05)
    mom, vom = np.zeros((m_, r_), np.float32), np.zeros((m_, r_), np.float32)
    q, _ = np.linalg.qr(rng.normal(size=(n_, r_)))
    p = q.astype(np.float32)
    t = 1
    fn = jax.jit(functools.partial(optim.coap_adam_step, transpose=False))
    out = fn(w, g, jnp.array(mom), jnp.array(vom), jnp.array(p),
             jnp.float32(0.9), jnp.float32(0.999), jnp.float32(0.02),
             jnp.float32(0.0))
    gp = np.array(g) @ p
    _, m2, v2 = np_adam(np.zeros_like(gp), gp, mom, vom, 1, 0.0)
    mh = m2 / (1 - 0.9)
    vh = v2 / (1 - 0.999)
    delta = mh / (np.sqrt(vh) + 1e-8)
    w2 = np.array(w) - 0.02 * (delta @ p.T)
    np.testing.assert_allclose(out[0], w2, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(out[1], m2, rtol=1e-4, atol=1e-7)


def test_transpose_side_rule():
    """For m < n the graph must project the row space (G^T's columns):
    running the transposed graph on G == running the plain graph on G^T."""
    rng = np.random.default_rng(2)
    m_, n_, r_ = 6, 10, 3   # m < n -> transpose frame
    w, g = arr(rng, m_, n_, scale=0.1), arr(rng, m_, n_, scale=0.05)
    mom = jnp.zeros((n_, r_))
    vom = jnp.zeros((n_, r_))
    q, _ = np.linalg.qr(rng.normal(size=(m_, r_)))
    p = jnp.array(q, jnp.float32)
    tr_fn = jax.jit(functools.partial(optim.coap_adam_step, transpose=True))
    plain_fn = jax.jit(functools.partial(optim.coap_adam_step, transpose=False))
    a = tr_fn(w, g, mom, vom, p, jnp.float32(0.9), jnp.float32(0.999),
              jnp.float32(0.01), jnp.float32(0.0))
    b = plain_fn(w.T, g.T, mom, vom, p, jnp.float32(0.9), jnp.float32(0.999),
                 jnp.float32(0.01), jnp.float32(0.0))
    np.testing.assert_allclose(np.array(a[0]), np.array(b[0]).T, rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_allclose(a[1], b[1], rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(a[3]), float(b[3]), rtol=1e-4)


def test_lora_step_updates_effective_weight():
    rng = np.random.default_rng(3)
    m_, n_, r_ = 8, 6, 2
    w = arr(rng, m_, n_, scale=0.1)
    a = arr(rng, r_, n_, scale=0.02)
    b = jnp.zeros((m_, r_))
    g = arr(rng, m_, n_, scale=0.05)
    zeros_a, zeros_b = jnp.zeros((r_, n_)), jnp.zeros((m_, r_))
    out = jax.jit(optim.lora_adam_step)(
        w, a, b, g, zeros_a, zeros_a, zeros_b, zeros_b,
        jnp.float32(0.9), jnp.float32(0.999), jnp.float32(0.01))
    w2, a2, b2 = np.array(out[0]), np.array(out[1]), np.array(out[2])
    # W' - W == B'A' - BA  (the adapter delta)
    np.testing.assert_allclose(
        w2 - np.array(w), b2 @ a2 - np.array(b) @ np.array(a),
        rtol=1e-4, atol=1e-6)
    # with B=0 init, dB = G A^T is nonzero -> B moves
    assert np.abs(b2).max() > 0


def test_conv_tucker2_step_shapes_and_direction():
    rng = np.random.default_rng(4)
    o, i, k = 8, 6, 3
    ro, ri = 4, 3
    w = arr(rng, o, i, k, k, scale=0.1)
    g = arr(rng, o, i, k, k, scale=0.05)
    mom = jnp.zeros((ro, ri, k, k))
    po = jnp.array(np.linalg.qr(rng.normal(size=(o, ro)))[0], jnp.float32)
    pi = jnp.array(np.linalg.qr(rng.normal(size=(i, ri)))[0], jnp.float32)
    out = jax.jit(optim.coap_adam_conv_step)(
        w, g, mom, mom, po, pi, jnp.float32(0.9), jnp.float32(0.999),
        jnp.float32(0.01), jnp.float32(0.0))
    assert out[0].shape == (o, i, k, k)
    assert out[1].shape == (ro, ri, k, k)
    # The update moves against the projected-restored gradient:
    dw = np.array(out[0]) - np.array(w)
    gproj = np.einsum("oikl,or,is->rskl", np.array(g), po, pi)
    grest = np.einsum("rskl,or,is->oikl", gproj, po, pi)
    # cos(dw, -grest) positive: Adam's per-coordinate normalization bends
    # the direction but must stay in the descent half-space.
    cos = -(dw * grest).sum() / (np.linalg.norm(dw) * np.linalg.norm(grest))
    assert cos > 0.5, cos
    assert float(out[3]) > 0  # ceu


def test_conv_recalib_orthonormal():
    rng = np.random.default_rng(5)
    o, i, k, ro, ri = 8, 6, 3, 4, 3
    g = arr(rng, o, i, k, k)
    po = jnp.array(np.linalg.qr(rng.normal(size=(o, ro)))[0], jnp.float32)
    p2 = jax.jit(functools.partial(optim.conv_recalib, mode=1))(po, g)
    assert p2.shape == (o, ro)
    np.testing.assert_allclose(np.array(p2.T @ p2), np.eye(ro), atol=2e-2)


def test_galore_svd_captures_energy():
    rng = np.random.default_rng(6)
    g = arr(rng, 20, 12, scale=1.0)
    p = jax.jit(functools.partial(optim.galore_svd, rank=4, transpose=False))(g)
    assert p.shape == (12, 4)
    q, _ = np.linalg.qr(rng.normal(size=(12, 4)))
    cap = np.linalg.norm(np.array(g) @ np.array(p))
    cap_rand = np.linalg.norm(np.array(g) @ q)
    assert cap > cap_rand
