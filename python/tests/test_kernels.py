"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, with
hypothesis sweeping shapes (including non-divisible-by-block sizes)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.projection_matmul import matmul
from compile.kernels.projected_update import adam_update
from compile.kernels.pupdate import cosgrad_rows

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

dims = st.integers(min_value=1, max_value=300)


def randf(rng, *shape):
    return jnp.array(rng.normal(size=shape), jnp.float32)


@given(m=dims, r=st.integers(1, 130), seed=st.integers(0, 2**31))
def test_adam_update_matches_ref(m, r, seed):
    rng = np.random.default_rng(seed)
    mm, vv, g = randf(rng, m, r), jnp.abs(randf(rng, m, r)), randf(rng, m, r)
    t = int(rng.integers(1, 1000))
    b1t, b2t = 0.9**t, 0.999**t
    out = adam_update(mm, vv, g, b1t, b2t)
    want = ref.adam_update_ref(mm, vv, g, b1t, b2t)
    for a, b in zip(out, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@given(m=st.integers(1, 200), k=st.integers(1, 200), n=st.integers(1, 200),
       seed=st.integers(0, 2**31))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = randf(rng, m, k), randf(rng, k, n)
    np.testing.assert_allclose(matmul(a, b), ref.matmul_ref(a, b),
                               rtol=1e-4, atol=1e-4)


@given(m=dims, n=st.integers(2, 200), seed=st.integers(0, 2**31))
def test_cosgrad_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    mhat, g = randf(rng, m, n), randf(rng, m, n)
    a, c = cosgrad_rows(mhat, g)
    ar, cr = ref.cosgrad_rows_ref(mhat, g)
    np.testing.assert_allclose(a, ar, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c, cr, rtol=1e-4, atol=1e-5)


def test_cosgrad_zero_rows_are_finite():
    """The exact failure that NaN'd embedding layers: zero gradient rows
    (unseen tokens) must produce zeros, not 0/0."""
    mhat = jnp.zeros((4, 8), jnp.float32)
    g = jnp.zeros((4, 8), jnp.float32)
    a, c = cosgrad_rows(mhat, g)
    assert np.all(np.isfinite(np.array(a)))
    assert np.all(np.array(a) == 0.0)
    assert np.all(np.array(c) == 0.0)
    # mixed: one live row, three dead rows
    g2 = g.at[0].set(1.0)
    m2 = mhat.at[0].set(0.5)
    a2, c2 = cosgrad_rows(m2, g2)
    assert np.all(np.isfinite(np.array(a2)))
    assert float(c2[0, 0]) == pytest.approx(1.0, abs=1e-5)


def test_cosgrad_cosine_semantics():
    rng = np.random.default_rng(0)
    g = randf(rng, 16, 32)
    # mhat parallel to g -> cos == 1 row-wise
    _, c = cosgrad_rows(2.5 * g, g)
    np.testing.assert_allclose(np.array(c), 1.0, atol=1e-5)
    # orthogonal rows -> cos == 0
    m = jnp.concatenate([g[:, 16:], -g[:, :16]], axis=1)
    _, c0 = cosgrad_rows(m, g)
    np.testing.assert_allclose(np.array(c0), 0.0, atol=1e-4)


def test_adafactor_update_semantics():
    rng = np.random.default_rng(1)
    m, r, c = jnp.zeros((8, 4)), jnp.zeros((8, 1)), jnp.zeros((1, 4))
    g = randf(rng, 8, 4)
    m2, r2, c2, delta = ref.adafactor_update_ref(m, r, c, g, t=1.0)
    assert m2.shape == (8, 4) and r2.shape == (8, 1) and c2.shape == (1, 4)
    # First step: delta direction matches the gradient sign.
    assert np.all(np.sign(delta) == np.sign(0.1 * np.array(g)))
