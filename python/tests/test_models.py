"""L2 model graphs: shapes, gradient flow to every parameter, loss
sanity, and the AOT registry/manifest contract."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.models import module_for
from compile.shapes import EXPERIMENTS, MODELS, param_specs


def init_params(cfg, rng):
    out = []
    for p in param_specs(cfg):
        if p.init == "ones":
            out.append(jnp.ones(p.shape, jnp.float32))
        elif p.init == "zeros":
            out.append(jnp.zeros(p.shape, jnp.float32))
        else:
            out.append(jnp.array(rng.normal(0, p.scale, p.shape), jnp.float32))
    return out


def make_data(cfg, rng):
    mod = module_for(cfg)
    data = []
    for name, shape, dtype in mod.data_specs(cfg):
        if dtype == jnp.int32:
            hi = {"tokens": getattr(cfg, "vocab", 2),
                  "targets": getattr(cfg, "vocab", 2),
                  "labels": getattr(cfg, "classes", 2),
                  "answers": getattr(cfg, "answers", 2)}.get(name, 2)
            data.append(jnp.array(rng.integers(0, hi, shape), jnp.int32))
        else:
            x = rng.normal(0, 1, shape).astype(np.float32)
            if name == "tvals":
                x = rng.uniform(0, 1, shape).astype(np.float32)
            data.append(jnp.array(x))
    return data


SMALL = ["lm_tiny", "vit_tiny", "cnn_tiny", "ctrl_small", "sit_small", "llava_small"]


@pytest.mark.parametrize("name", SMALL)
def test_loss_finite_and_grads_flow_everywhere(name):
    cfg = MODELS[name]
    mod = module_for(cfg)
    rng = np.random.default_rng(0)
    params = init_params(cfg, rng)
    data = make_data(cfg, rng)
    loss, grads = jax.value_and_grad(
        lambda ps: mod.loss_fn(ps, *data, cfg=cfg))(tuple(params))
    assert np.isfinite(float(loss)), name
    specs = param_specs(cfg)
    assert len(grads) == len(specs)
    for g, s in zip(grads, specs):
        assert g.shape == s.shape, s.name
        assert bool(jnp.all(jnp.isfinite(g))), s.name
        # every trainable tensor receives signal (embeddings may have
        # zero rows but never an all-zero gradient)
        assert float(jnp.abs(g).sum()) > 0, f"no gradient into {s.name}"


def test_lm_loss_at_init_is_log_vocab():
    cfg = MODELS["lm_tiny"]
    mod = module_for(cfg)
    rng = np.random.default_rng(1)
    params = init_params(cfg, rng)
    data = make_data(cfg, rng)
    loss = mod.loss_fn(tuple(params), *data, cfg=cfg)
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


def test_vit_eval_counts_correct():
    cfg = MODELS["vit_tiny"]
    mod = module_for(cfg)
    rng = np.random.default_rng(2)
    params = init_params(cfg, rng)
    data = make_data(cfg, rng)
    loss, ncorrect = mod.eval_fn(tuple(params), *data, cfg=cfg)
    assert 0 <= float(ncorrect) <= cfg.batch
    assert np.isfinite(float(loss))


def test_control_branch_changes_prediction():
    cfg = MODELS["ctrl_small"]
    mod = module_for(cfg)
    rng = np.random.default_rng(3)
    params = init_params(cfg, rng)
    noisy, clean, control = make_data(cfg, rng)
    _, pred1 = mod.eval_fn(tuple(params), noisy, clean, control, cfg=cfg)
    _, pred2 = mod.eval_fn(tuple(params), noisy, clean, control * 0.0, cfg=cfg)
    assert float(jnp.abs(pred1 - pred2).max()) > 0, \
        "control input does not reach the prediction"


def test_patchify_roundtrip():
    from compile.models import layers
    rng = np.random.default_rng(4)
    x = jnp.array(rng.normal(size=(2, 3, 16, 16)), jnp.float32)
    t = layers.patchify(x, 4)
    assert t.shape == (2, 16, 48)
    back = layers.unpatchify(t, 4, 3, 16)
    np.testing.assert_allclose(back, x)


# ---------------------------------------------------------------------------
# AOT registry / manifest contract
# ---------------------------------------------------------------------------

def test_registry_covers_every_experiment_model():
    reg = aot.build_registry()
    for e in EXPERIMENTS:
        assert f"train_step__{e.model}" in reg, e.id
        assert f"eval_step__{e.model}" in reg, e.id


def test_registry_names_follow_convention():
    reg = aot.build_registry()
    for name, gd in reg.items():
        assert name == gd.name
        assert "__" in name
        entry = gd.manifest_entry()
        assert entry["file"] == name + ".hlo.txt"
        assert len(entry["inputs"]) >= 1
        assert len(entry["outputs"]) >= 1


def test_matrix_graph_shape_contract():
    reg = aot.build_registry()
    gd = reg.get("coap_adam_step__2048x256_r64")
    assert gd is not None
    e = gd.manifest_entry()
    shapes = [tuple(i["shape"]) for i in e["inputs"]]
    # w, g, m, v, p, b1t, b2t, lr, wd
    assert shapes[0] == (2048, 256)
    assert shapes[2] == (2048, 64)   # moments on the max side
    assert shapes[4] == (256, 64)    # projection on the min side
    assert shapes[5] == ()
    outs = [tuple(o["shape"]) for o in e["outputs"]]
    assert outs[0] == (2048, 256) and outs[3] == ()
