"""Pure-jnp linear algebra for the AOT path.

jnp.linalg.{svd,qr} lower to jaxlib-registered LAPACK FFI custom-calls
that xla_extension 0.5.1 (the runtime behind the Rust `xla` crate) cannot
resolve, so every decomposition used at training time is implemented here
from primitive HLO ops only (dots, gathers/scatters, while-loops):

  * `mgs_qr`            — modified Gram-Schmidt reduced QR (two passes).
  * `onesided_jacobi`   — one-sided Jacobi column-orthogonalization, the
                          building block of both SVDs below. Round-robin
                          (circle-method) pair scheduling makes every
                          sweep n-1 rounds of n/2 *independent* rotations,
                          which vectorizes into gathers + 2-column GEMV
                          updates (no O(n^2) sequential scalar rotations).
  * `svd_topk`          — full(ish) SVD of G via Jacobi, returning the
                          top-r right singular vectors. Cost O(mn^2) per
                          sweep — intentionally expensive: this *is*
                          GaLore's projection step whose cost the paper
                          benchmarks against (Sec. 3.2, challenge 2).
  * `lowcost_recalib`   — the paper's Eqn. 7: Q = QR_red(G P), small SVD
                          of Q^T G via Jacobi on the (n, r) side. Cost
                          O(mnr + nr^2) — the 20x-cheaper path.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

QR_EPS = 1e-12


# ---------------------------------------------------------------------------
# Modified Gram-Schmidt reduced QR
# ---------------------------------------------------------------------------

def mgs_qr(x):
    """Reduced QR of x (m, r), m >= r, via two-pass modified Gram-Schmidt.

    Returns Q (m, r) with (numerically) orthonormal columns spanning
    range(x). Rank-deficient columns degrade to near-zero columns rather
    than NaNs (guarded normalization) — acceptable for Eqn. 7, where Q is
    only used as an approximate range basis.
    """
    m, r = x.shape

    def body(j, q):
        v = lax.dynamic_slice(x, (0, j), (m, 1))  # (m, 1)
        # Two projection passes for numerical stability. Columns >= j of q
        # are still zero, so projecting against all of q is a no-op there.
        for _ in range(2):
            coef = q.T @ v                      # (r, 1)
            v = v - q @ coef
        norm = jnp.sqrt(jnp.sum(v * v)) + QR_EPS
        v = v / norm
        return lax.dynamic_update_slice(q, v, (0, j))

    q0 = jnp.zeros((m, r), dtype=x.dtype)
    return lax.fori_loop(0, r, body, q0)


# ---------------------------------------------------------------------------
# One-sided Jacobi
# ---------------------------------------------------------------------------

def _round_pairs(k, n):
    """Circle-method round-robin pairing for round k of n players (n even).

    Player n-1 is fixed; players 0..n-2 rotate. Returns (a_idx, b_idx),
    each (n/2,), pairing a_idx[i] with b_idx[i]; over k = 0..n-2 every
    unordered pair appears exactly once.
    """
    half = n // 2
    i = jnp.arange(half)
    nm1 = n - 1
    a = jnp.where(i == 0, nm1, (k + i) % nm1)
    b = (k - i + nm1) % nm1
    b = jnp.where(i == 0, k % nm1, b)
    return a, b


def onesided_jacobi(x, sweeps=8, compute_v=False):
    """Orthogonalize the columns of x (m, n) by Jacobi rotations.

    After enough sweeps, x_out = X V has orthogonal columns with norms
    equal to the singular values of X. If compute_v, also accumulates and
    returns V (n, n). n odd is handled by padding a zero column (rotations
    against a zero column are identities).
    """
    m, n = x.shape
    padded = n % 2 == 1
    if padded:
        x = jnp.pad(x, ((0, 0), (0, 1)))
        n += 1
    half = n // 2
    v = jnp.eye(n, dtype=x.dtype) if compute_v else jnp.zeros((1, 1), x.dtype)

    def rotate(mat, a_idx, b_idx, c, s):
        """Apply per-pair Givens rotations to columns (a_idx[i], b_idx[i])."""
        cols_a = mat.T[a_idx]                  # (half, rows)
        cols_b = mat.T[b_idx]
        new_a = c[:, None] * cols_a - s[:, None] * cols_b
        new_b = s[:, None] * cols_a + c[:, None] * cols_b
        mt = mat.T
        mt = mt.at[a_idx].set(new_a)
        mt = mt.at[b_idx].set(new_b)
        return mt.T

    def round_body(k, carry):
        xc, vc = carry
        a_idx, b_idx = _round_pairs(k, n)
        cols_a = xc.T[a_idx]                   # (half, m)
        cols_b = xc.T[b_idx]
        alpha = jnp.sum(cols_a * cols_a, axis=1)
        beta = jnp.sum(cols_b * cols_b, axis=1)
        gamma = jnp.sum(cols_a * cols_b, axis=1)
        # Rotation zeroing the off-diagonal gamma (Rutishauser formulas).
        safe = jnp.abs(gamma) > 1e-20
        zeta = (beta - alpha) / (2.0 * jnp.where(safe, gamma, 1.0))
        # sign(0) must be +1 here (zeta == 0 is a 45-degree rotation).
        sz = jnp.where(zeta >= 0.0, 1.0, -1.0)
        t = sz / (jnp.abs(zeta) + jnp.sqrt(1.0 + zeta * zeta))
        t = jnp.where(safe, t, 0.0)
        c = 1.0 / jnp.sqrt(1.0 + t * t)
        s = c * t
        xc = rotate(xc, a_idx, b_idx, c, s)
        if compute_v:
            vc = rotate(vc, a_idx, b_idx, c, s)
        return xc, vc

    def sweep_body(_, carry):
        return lax.fori_loop(0, n - 1, round_body, carry)

    x, v = lax.fori_loop(0, sweeps, sweep_body, (x, v))
    if padded:
        x = x[:, :-1]
        if compute_v:
            v = v[:-1, :-1]  # safe: the pad column never mixes (zero gamma)
    return (x, v) if compute_v else (x, None)


def _sort_desc_by_norm(y, extra=None):
    """Sort columns of y by descending norm; apply same order to extra."""
    norms = jnp.sqrt(jnp.sum(y * y, axis=0))
    order = jnp.argsort(-norms)
    y = y[:, order]
    norms = norms[order]
    if extra is not None:
        extra = extra[:, order]
    return y, norms, extra


def svd_topk(g, rank, sweeps=8):
    """Top-`rank` right singular vectors of g (m, n): GaLore's SVD step.

    Returns (p, sigma) with p (n, rank) orthonormal. Full one-sided Jacobi
    on all n columns — O(mn^2) work per sweep, the expensive baseline.
    """
    y, v = onesided_jacobi(g, sweeps=sweeps, compute_v=True)
    _, sigma, v_sorted = _sort_desc_by_norm(y, v)
    return v_sorted[:, :rank], sigma[:rank]


def lowcost_recalib(g, p_prev, sweeps=8):
    """The paper's Eqn. 7 — occasional low-cost SVD recalibration.

        Q_red = QR_red(G P_prev)           (m, r)
        U S Z^T = SVD(Q_red^T G)           (r, n) small SVD
        P_t = Z                            (n, r)

    The small SVD runs one-sided Jacobi on B^T = (Q^T G)^T (n, r): after
    rotations Y = B^T V has orthogonal columns with norms sigma, and the
    right singular vectors of B are Z = Y diag(1/sigma). Total cost
    O(mnr + mr^2 + nr^2) vs O(mn^2) for svd_topk.
    """
    q = mgs_qr(g @ p_prev)                   # (m, r)
    b = q.T @ g                              # (r, n)
    y, _ = onesided_jacobi(b.T, sweeps=sweeps, compute_v=False)  # (n, r)
    y, sigma, _ = _sort_desc_by_norm(y)
    z = y / (sigma[None, :] + QR_EPS)
    return z


# ---------------------------------------------------------------------------
# Eqn. 6 — inter-projection correlation-aware P update (SGD on the product
# objective). The row-wise CosSim gradient pieces come from the L1 kernel.
# ---------------------------------------------------------------------------

def pupdate_sgd(p, g, m_proj, iters=2, lr=0.1, cosgrad_rows_fn=None):
    """SGD iterations on Eqn. 6: min_P MSE(GPP^T, G) * (1 - CosSim(MP^T, G)).

    Gradient (appendix Eqns. 3-7, with the descent sign on the CosSim term
    — the appendix writes `+ dCos * MSE` inside the update, which ascends
    the (1 - CosSim) factor; we use the mathematically consistent
    `- dCos * MSE`):

        dL/dP = dMSE/dP * (1 - cos) - dCos/dP * mse
        dMSE/dP = 2/(mn) (Ghat^T G P - 2 G^T G P + G^T Ghat P)
        dCos/dP = 1/m * A^T M_proj          (A from the L1 kernel)
    """
    if cosgrad_rows_fn is None:
        from .kernels import cosgrad_rows as cosgrad_rows_fn
    m, n = g.shape

    def body(_, p):
        gp = g @ p                            # (m, r)
        ghat = gp @ p.T                       # (m, n)
        diff = ghat - g
        mse = jnp.mean(diff * diff)
        gtg_p = g.T @ gp                      # G^T G P   (n, r)
        dmse = (2.0 / (m * n)) * (ghat.T @ gp - 2.0 * gtg_p + g.T @ (ghat @ p))
        mhat = m_proj @ p.T                   # (m, n)
        a, cos_rows = cosgrad_rows_fn(mhat, g)
        cos = jnp.mean(cos_rows)
        dcos = (a.T @ m_proj) / m             # (n, r)
        grad = dmse * (1.0 - cos) - dcos * mse
        return p - lr * grad

    return lax.fori_loop(0, iters, body, p)
