"""L2 model graphs. Each module exposes loss_fn / data_specs (and for
models with quality metrics, eval_fn / eval_outputs)."""

from . import cnn, layers, llava, sit, transformer, vit


def module_for(cfg):
    return {
        "lm": transformer,
        "vit": vit,
        "cnn": cnn,
        "sit": sit,
        "llava": llava,
    }[cfg.family]


__all__ = ["cnn", "layers", "llava", "sit", "transformer", "vit", "module_for"]
