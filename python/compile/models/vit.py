"""L2 model: ViT classifier (DeiT-Base on CIFAR-100 substitute; Fig 3/4,
Table 7).

Data inputs: images (B, C, H, W) f32, labels (B,) i32.
Eval graph additionally returns n_correct for accuracy.
"""

import jax.numpy as jnp

from . import layers


def _logits(params, images, cfg):
    it = iter(params)
    patch_embed = next(it)
    pos_embed = next(it)
    x = layers.patchify(images, cfg.patch) @ patch_embed + pos_embed[None]
    for _ in range(cfg.layers):
        x = layers.transformer_block(x, it, cfg.heads, causal=False)
    lnf = next(it)
    head = next(it)
    x = layers.rms_norm(jnp.mean(x, axis=1), lnf)   # mean-pool tokens
    logits = x @ head
    rest = list(it)
    assert not rest, f"unconsumed params: {len(rest)}"
    return logits


def loss_fn(params, images, labels, cfg):
    return layers.cross_entropy(_logits(params, images, cfg), labels)


def eval_fn(params, images, labels, cfg):
    logits = _logits(params, images, cfg)
    return layers.cross_entropy(logits, labels), layers.n_correct(logits, labels)


def data_specs(cfg):
    return [
        ("images", (cfg.batch, cfg.chans, cfg.img, cfg.img), jnp.float32),
        ("labels", (cfg.batch,), jnp.int32),
    ]


def eval_outputs(cfg):
    return ["loss", "n_correct"]
