"""L2 model: small conv denoiser (LDM / DDPM U-Net substitute; Table 1,
Appendix Table 2) and its ControlNet variant (Table 3).

Plain variant inputs:  noisy (B,C,H,W), clean (B,C,H,W). Loss: MSE.
Control variant adds:  control (B,1,H,W) — a keypoint-blob map injected
into the mid features through a zero-initialized-style side branch,
mirroring ControlNet's architecture at toy scale.

Eval graphs also return the prediction so the Rust harness can compute
the FID-proxy / keypoint-mAP-proxy metrics.
"""

import jax.numpy as jnp
from jax import lax

from . import layers


def _conv(x, w, b):
    """Same-padded stride-1 conv, NCHW x OIHW."""
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + b[None, :, None, None]


def _predict(params, noisy, control, cfg):
    it = iter(params)
    n_body = len(cfg.widths)
    mid = n_body // 2
    x = noisy
    ctrl_feat = None
    body = []
    for _ in range(n_body):
        body.append((next(it), next(it)))
    w_out, b_out = next(it), next(it)
    if cfg.control:
        c0w, c0b = next(it), next(it)
        c1w, c1b = next(it), next(it)
        h = layers.gelu(_conv(control, c0w, c0b))
        ctrl_feat = _conv(h, c1w, c1b)
    rest = list(it)
    assert not rest, f"unconsumed params: {len(rest)}"

    for i, (w, b) in enumerate(body):
        x = layers.gelu(_conv(x, w, b))
        if cfg.control and i == mid and ctrl_feat is not None:
            x = x + ctrl_feat
    return noisy + _conv(x, w_out, b_out)  # residual prediction


def loss_fn_plain(params, noisy, clean, cfg):
    pred = _predict(params, noisy, None, cfg)
    return jnp.mean((pred - clean) ** 2)


def loss_fn_control(params, noisy, clean, control, cfg):
    pred = _predict(params, noisy, control, cfg)
    return jnp.mean((pred - clean) ** 2)


def loss_fn(params, *data, cfg):
    if cfg.control:
        return loss_fn_control(params, *data, cfg=cfg)
    return loss_fn_plain(params, *data, cfg=cfg)


def eval_fn(params, *data, cfg):
    noisy, clean = data[0], data[1]
    control = data[2] if cfg.control else None
    pred = _predict(params, noisy, control, cfg)
    return jnp.mean((pred - clean) ** 2), pred


def data_specs(cfg):
    s = [
        ("noisy", (cfg.batch, cfg.chans, cfg.img, cfg.img), jnp.float32),
        ("clean", (cfg.batch, cfg.chans, cfg.img, cfg.img), jnp.float32),
    ]
    if cfg.control:
        s.append(("control", (cfg.batch, 1, cfg.img, cfg.img), jnp.float32))
    return s


def eval_outputs(cfg):
    return ["loss", "pred"]
