"""L2 model: decoder-only LLaMA-style language model (Table 5 substitute).

Data inputs: tokens (B, S) i32, targets (B, S) i32 (pre-shifted by the
Rust data pipeline). Loss: mean next-token cross-entropy.
"""

import jax.numpy as jnp

from . import layers


def loss_fn(params, tokens, targets, cfg):
    it = iter(params)
    embed = next(it)
    x = embed[tokens]                       # (B, S, d)
    for _ in range(cfg.layers):
        x = layers.transformer_block(x, it, cfg.heads, causal=True)
    lnf = next(it)
    head = next(it)
    x = layers.rms_norm(x, lnf)
    logits = x @ head                       # (B, S, V)
    loss = layers.cross_entropy(logits, targets)
    rest = list(it)
    assert not rest, f"unconsumed params: {len(rest)}"
    return loss


def data_specs(cfg):
    return [
        ("tokens", (cfg.batch, cfg.seq), jnp.int32),
        ("targets", (cfg.batch, cfg.seq), jnp.int32),
    ]


def eval_outputs(cfg):
    return ["loss"]
