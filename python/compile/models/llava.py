"""L2 model: LLaVA-style multimodal stub (Table 6 substitute).

Frozen 'CLIP' features (B, F) are mapped by a trainable projector into a
prefix token, concatenated with the embedded question tokens, run through
a causal trunk; the final position classifies over answer classes.
Data inputs: feats (B,F) f32, tokens (B,S) i32, answers (B,) i32.
"""

import jax.numpy as jnp

from . import layers


def _logits(params, feats, tokens, cfg):
    it = iter(params)
    projector = next(it)
    embed = next(it)
    prefix = (feats @ projector)[:, None, :]       # (B, 1, d)
    x = jnp.concatenate([prefix, embed[tokens]], axis=1)  # (B, 1+S, d)
    for _ in range(cfg.layers):
        x = layers.transformer_block(x, it, cfg.heads, causal=True)
    lnf = next(it)
    head = next(it)
    x = layers.rms_norm(x[:, -1, :], lnf)
    logits = x @ head
    rest = list(it)
    assert not rest, f"unconsumed params: {len(rest)}"
    return logits


def loss_fn(params, feats, tokens, answers, cfg):
    return layers.cross_entropy(_logits(params, feats, tokens, cfg), answers)


def eval_fn(params, feats, tokens, answers, cfg):
    logits = _logits(params, feats, tokens, cfg)
    return (layers.cross_entropy(logits, answers),
            layers.n_correct(logits, answers))


def data_specs(cfg):
    return [
        ("feats", (cfg.batch, cfg.feat), jnp.float32),
        ("tokens", (cfg.batch, cfg.seq), jnp.int32),
        ("answers", (cfg.batch,), jnp.int32),
    ]


def eval_outputs(cfg):
    return ["loss", "n_correct"]
