"""Shared L2 building blocks for all model graphs.

Every model consumes its parameter list positionally in the exact order
declared by `shapes.*_param_specs` — the manifest, the Rust parameter
store, and these apply functions all share that single ordering.
"""

import jax.numpy as jnp


def rms_norm(x, scale, eps=1e-6):
    """RMSNorm (scale-only), LLaMA-style."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * scale / jnp.sqrt(var + eps)


def attention(x, wq, wk, wv, wo, heads, causal):
    """Multi-head self-attention. x: (B, T, d)."""
    b, t, d = x.shape
    dh = d // heads
    q = (x @ wq).reshape(b, t, heads, dh).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, t, heads, dh).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, t, heads, dh).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ wo


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608 * (x + 0.044715 * x ** 3)))


def mlp(x, w1, w2):
    return gelu(x @ w1) @ w2


def transformer_block(x, it, heads, causal):
    """Pre-norm block consuming 8 params from iterator `it` in spec order:
    ln1, wq, wk, wv, wo, ln2, w1, w2."""
    ln1 = next(it)
    wq, wk, wv, wo = next(it), next(it), next(it), next(it)
    ln2 = next(it)
    w1, w2 = next(it), next(it)
    x = x + attention(rms_norm(x, ln1), wq, wk, wv, wo, heads, causal)
    x = x + mlp(rms_norm(x, ln2), w1, w2)
    return x


def cross_entropy(logits, labels):
    """Mean CE. logits (..., V), labels (...) int32. Returns scalar."""
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    logz = jnp.log(jnp.sum(jnp.exp(logits), axis=-1))
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def n_correct(logits, labels):
    return jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def patchify(images, patch):
    """(B, C, H, W) -> (B, T, C*patch*patch) row-major patch grid."""
    b, c, h, w = images.shape
    gh, gw = h // patch, w // patch
    x = images.reshape(b, c, gh, patch, gw, patch)
    x = x.transpose(0, 2, 4, 1, 3, 5)  # (B, gh, gw, C, p, p)
    return x.reshape(b, gh * gw, c * patch * patch)


def unpatchify(tokens, patch, chans, img):
    """Inverse of patchify: (B, T, C*p*p) -> (B, C, H, W)."""
    b = tokens.shape[0]
    g = img // patch
    x = tokens.reshape(b, g, g, chans, patch, patch)
    x = x.transpose(0, 3, 1, 4, 2, 5)
    return x.reshape(b, chans, img, img)
