"""L2 optimizer step graphs — one HLO executable per (template, shape).

Every graph is a pure function (state in, state out); the Rust coordinator
owns all state between steps and decides *when* each graph runs (the
T_u / lambda schedule of Algorithm 1). Scalars (lr, wd, beta powers, t)
are graph inputs so Rust can drive schedules without recompilation.

Projection frame convention (GaLore side rule, DESIGN.md §6): for
W (m, n) the graphs internally operate on Gn = G if m >= n else G^T, so
the projection P always lives on the smaller side: P (n', r) with
n' = min(m, n), and moments are (m', r) with m' = max(m, n). The manifest
records the exact I/O shapes, so the Rust side never needs the rule.

Betas/eps follow the paper: beta1=0.9, beta2=0.999, eps=1e-8; Adafactor
decay gamma=-0.8; Eqn-6 SGD: 2 iterations at lr=0.1 (appendix §1.1).
"""

import jax.numpy as jnp

from . import kernels, linalg

BETA1 = 0.9
BETA2 = 0.999
PUPDATE_ITERS = 2
PUPDATE_LR = 0.1
SVD_SWEEPS = 8


def _norm(g, transpose):
    return g.T if transpose else g


# ---------------------------------------------------------------------------
# Matrix steps
# ---------------------------------------------------------------------------

def coap_adam_step(w, g, m, v, p, b1t, b2t, lr, wd, *, transpose):
    """Projected Adam step (Algorithm 1 inner body).

    w, g: (m, n); m, v: (m', r); p: (n', r). Returns (w', m', v', ceu).
    Used by COAP, GaLore and Flora alike — they differ only in how the
    coordinator refreshes P.
    """
    gn = _norm(g, transpose)
    g_proj = kernels.matmul(gn, p)                     # (m', r)
    m_new, v_new, delta = kernels.adam_update(m, v, g_proj, b1t, b2t,
                                              beta1=BETA1, beta2=BETA2)
    dw = kernels.matmul(delta, p.T)                    # (m', n')
    dw = _norm(dw, transpose)
    w_new = w - lr * (dw + wd * w)
    ceu = jnp.sum(jnp.abs(w_new - w))
    return w_new, m_new, v_new, ceu


def coap_adafactor_step(w, g, m, r_, c_, p, t, lr, *, transpose):
    """Projected Adafactor-with-momentum step (appendix Algorithm 2).

    m: (m', r); r_: (m', 1); c_: (1, r); p: (n', r).
    Returns (w', m', r', c', ceu).
    """
    gn = _norm(g, transpose)
    g_proj = kernels.matmul(gn, p)
    m_new, r_new, c_new, delta = kernels.adafactor_update(
        m, r_, c_, g_proj, t, beta1=BETA1)
    dw = kernels.matmul(delta, p.T)
    dw = _norm(dw, transpose)
    w_new = w - lr * dw
    ceu = jnp.sum(jnp.abs(w_new - w))
    return w_new, m_new, r_new, c_new, ceu


def adam_step(w, g, m, v, b1t, b2t, lr, wd):
    """Full-rank Adam(W) baseline. All operands (m, n)."""
    m_new, v_new, delta = kernels.adam_update(m, v, g, b1t, b2t,
                                              beta1=BETA1, beta2=BETA2)
    w_new = w - lr * (delta + wd * w)
    ceu = jnp.sum(jnp.abs(w_new - w))
    return w_new, m_new, v_new, ceu


def adafactor_step(w, g, m, r_, c_, t, lr):
    """Full-rank Adafactor-with-momentum baseline."""
    m_new, r_new, c_new, delta = kernels.adafactor_update(
        m, r_, c_, g, t, beta1=BETA1)
    w_new = w - lr * delta
    ceu = jnp.sum(jnp.abs(w_new - w))
    return w_new, m_new, r_new, c_new, ceu


def pupdate(p, g, m_proj, *, transpose):
    """Eqn-6 inter-projection correlation-aware P update (2 SGD iters)."""
    gn = _norm(g, transpose)
    return linalg.pupdate_sgd(p, gn, m_proj, iters=PUPDATE_ITERS,
                              lr=PUPDATE_LR,
                              cosgrad_rows_fn=kernels.cosgrad_rows)


def recalib(p, g, *, transpose):
    """Eqn-7 occasional low-cost SVD recalibration."""
    gn = _norm(g, transpose)
    return linalg.lowcost_recalib(gn, p, sweeps=SVD_SWEEPS)


def galore_svd(g, *, rank, transpose):
    """GaLore's full SVD projection refresh (expensive baseline)."""
    gn = _norm(g, transpose)
    p, _ = linalg.svd_topk(gn, rank, sweeps=SVD_SWEEPS)
    return p


def lora_adam_step(w, a, b, g, ma, va, mb, vb, b1t, b2t, lr):
    """Optimizer-level LoRA baseline (DESIGN.md §3).

    Effective weight w = w0 + b @ a is maintained directly; the adapter
    gradients come from the full gradient: dA = B^T G, dB = G A^T. ReLoRA
    is this plus a coordinator-side periodic merge (reset a, b, moments).
    a: (r, n), b: (m, r). Returns (w', a', b', ma', va', mb', vb', ceu).
    """
    da = b.T @ g                                      # (r, n)
    db = g @ a.T                                      # (m, r)
    ma_new, va_new, delta_a = kernels.adam_update(ma, va, da, b1t, b2t,
                                                  beta1=BETA1, beta2=BETA2)
    mb_new, vb_new, delta_b = kernels.adam_update(mb, vb, db, b1t, b2t,
                                                  beta1=BETA1, beta2=BETA2)
    a_new = a - lr * delta_a
    b_new = b - lr * delta_b
    w_new = w + b_new @ a_new - b @ a
    ceu = jnp.sum(jnp.abs(w_new - w))
    return w_new, a_new, b_new, ma_new, va_new, mb_new, vb_new, ceu


# ---------------------------------------------------------------------------
# Conv (Tucker-2) steps — appendix Algorithm 3
# ---------------------------------------------------------------------------

def _mode1(g4, po):
    """G x1 PO^T : (O,I,K,K) -> (rO,I,K,K)."""
    return jnp.einsum("oikl,or->rikl", g4, po)


def _mode2(g4, pi):
    """G x2 PI^T : (*,I,K,K) -> (*,rI,K,K)."""
    return jnp.einsum("xikl,is->xskl", g4, pi)


def _unfold1(g4):
    o = g4.shape[0]
    return g4.reshape(o, -1)


def _unfold2(g4):
    i = g4.shape[1]
    return jnp.transpose(g4, (1, 0, 2, 3)).reshape(i, -1)


def coap_adam_conv_step(w, g, m, v, po, pi, b1t, b2t, lr, wd):
    """Tucker-2 projected Adam for conv weights (O,I,K1,K2).

    m, v: (rO, rI, K1, K2). Returns (w', m', v', ceu).
    """
    ro, ri = po.shape[1], pi.shape[1]
    k1, k2 = g.shape[2], g.shape[3]
    g_proj = _mode2(_mode1(g, po), pi)                 # (rO,rI,K,K)
    m2, v2, g2 = (x.reshape(ro, ri * k1 * k2) for x in (m, v, g_proj))
    m_new, v_new, delta = kernels.adam_update(m2, v2, g2, b1t, b2t,
                                              beta1=BETA1, beta2=BETA2)
    delta4 = delta.reshape(ro, ri, k1, k2)
    dw = jnp.einsum("rskl,or,is->oikl", delta4, po, pi)
    w_new = w - lr * (dw + wd * w)
    ceu = jnp.sum(jnp.abs(w_new - w))
    return (w_new, m_new.reshape(ro, ri, k1, k2),
            v_new.reshape(ro, ri, k1, k2), ceu)


def coap_adafactor_conv_step(w, g, m, r_, c_, po, pi, t, lr):
    """Tucker-2 projected Adafactor for conv weights.

    m: (rO, rI, K1, K2); r_: (rO, 1); c_: (1, rI*K1*K2).
    Returns (w', m', r', c', ceu).
    """
    ro, ri = po.shape[1], pi.shape[1]
    k1, k2 = g.shape[2], g.shape[3]
    g_proj = _mode2(_mode1(g, po), pi).reshape(ro, ri * k1 * k2)
    m2 = m.reshape(ro, ri * k1 * k2)
    m_new, r_new, c_new, delta = kernels.adafactor_update(
        m2, r_, c_, g_proj, t, beta1=BETA1)
    delta4 = delta.reshape(ro, ri, k1, k2)
    dw = jnp.einsum("rskl,or,is->oikl", delta4, po, pi)
    w_new = w - lr * dw
    ceu = jnp.sum(jnp.abs(w_new - w))
    return w_new, m_new.reshape(ro, ri, k1, k2), r_new, c_new, ceu


def coap_adam_convfull_step(w, g, m, v, po, pi, ps, b1t, b2t, lr, wd):
    """'Full' Tucker variant for App. Fig 1: Tucker-2 plus a projection of
    the combined spatial mode (K1*K2 -> rS). m, v: (rO, rI, rS)."""
    ro, ri, rs = po.shape[1], pi.shape[1], ps.shape[1]
    k1, k2 = g.shape[2], g.shape[3]
    g_proj = _mode2(_mode1(g, po), pi).reshape(ro, ri, k1 * k2)
    g_proj = jnp.einsum("xys,st->xyt", g_proj, ps)     # (rO,rI,rS)
    m2, v2, g2 = (x.reshape(ro, ri * rs) for x in (m, v, g_proj))
    m_new, v_new, delta = kernels.adam_update(m2, v2, g2, b1t, b2t,
                                              beta1=BETA1, beta2=BETA2)
    delta3 = delta.reshape(ro, ri, rs)
    dk = jnp.einsum("xyt,st->xys", delta3, ps).reshape(ro, ri, k1, k2)
    dw = jnp.einsum("rskl,or,is->oikl", dk, po, pi)
    w_new = w - lr * (dw + wd * w)
    ceu = jnp.sum(jnp.abs(w_new - w))
    return (w_new, m_new.reshape(ro, ri, rs), v_new.reshape(ro, ri, rs), ceu)


def conv_pupdate(p, g, m_proj, other_p, *, mode):
    """Eqn-6 update for PO (mode=1) or PI (mode=2) of a conv layer.

    m_proj is the Tucker-2 projected moment (rO, rI, K1, K2); we restore
    it along the *other* mode, unfold along this mode, and run the matrix
    update in the normalized (transposed) frame where P sits on the small
    side.
    """
    if mode == 1:
        m_part = _mode_restore2(m_proj, other_p)       # (rO, I, K, K)
        gn = _unfold1(g).T                             # (IKK, O)
        mn = _unfold1(m_part).T                        # (IKK, rO)
    else:
        m_part = _mode_restore1(m_proj, other_p)       # (O, rI, K, K)
        gn = _unfold2(g).T                             # (OKK, I)
        mn = _unfold2(m_part).T                        # (OKK, rI)
    return linalg.pupdate_sgd(p, gn, mn, iters=PUPDATE_ITERS, lr=PUPDATE_LR,
                              cosgrad_rows_fn=kernels.cosgrad_rows)


def _mode_restore1(t4, po):
    """(rO,*,K,K) x1 PO -> (O,*,K,K)."""
    return jnp.einsum("rikl,or->oikl", t4, po)


def _mode_restore2(t4, pi):
    """(*,rI,K,K) x2 PI -> (*,I,K,K)."""
    return jnp.einsum("xskl,is->xikl", t4, pi)


def conv_recalib(p, g, *, mode):
    """Eqn-7 recalibration on the mode-1/mode-2 unfolding of G."""
    gn = (_unfold1(g) if mode == 1 else _unfold2(g)).T
    return linalg.lowcost_recalib(gn, p, sweeps=SVD_SWEEPS)


def conv_svd(g, *, rank, mode):
    """GaLore-style full SVD on the unfolding (expensive conv baseline)."""
    gn = (_unfold1(g) if mode == 1 else _unfold2(g)).T
    p, _ = linalg.svd_topk(gn, rank, sweeps=SVD_SWEEPS)
    return p
