"""Model / experiment shape census — the single source of truth.

Every model architecture and every experiment (paper table / figure) is
declared here. ``aot.py`` derives from these declarations the exact set of
(graph-template, shape) instantiations to lower, and emits the same
information into ``artifacts/manifest.json`` so the Rust coordinator never
re-derives architecture.

Paper mapping (see DESIGN.md §5):
  lm_*        -> Table 5 (LLaMA-1B/7B substitutes) + end-to-end driver
  vit_*       -> Fig 3/4, Table 7 (DeiT-Base on CIFAR-100 substitute)
  cnn_*       -> Table 1 / Appendix Table 2 (LDM / DDPM U-Net substitutes)
  sit_small   -> Table 2 (SiT-XL/2 substitute)
  ctrl_small  -> Table 3 (ControlNet-SDXL substitute)
  llava_small -> Table 6 (LLaVA-v1.5-7B fine-tune substitute)
"""

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamSpec:
    """One trainable tensor in a model.

    kind:   'matrix' (2-D, low-rank-projectable), 'conv' (4-D OIHW,
            Tucker-2-projectable), or 'vector' (updated full-rank on the
            Rust side with the refimpl optimizer).
    init:   'normal' | 'zeros' | 'ones'
    scale:  stddev for 'normal' init.
    """

    name: str
    shape: Tuple[int, ...]
    kind: str = "matrix"
    init: str = "normal"
    scale: float = 0.02

    @property
    def numel(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LmConfig:
    name: str
    d: int
    layers: int
    heads: int
    vocab: int
    seq: int
    batch: int
    family: str = "lm"

    @property
    def mlp(self) -> int:
        return 4 * self.d


@dataclass(frozen=True)
class VitConfig:
    """ViT classifier (DeiT substitute). Also the trunk for sit/llava."""

    name: str
    d: int
    layers: int
    heads: int
    img: int
    patch: int
    chans: int
    classes: int
    batch: int
    family: str = "vit"

    @property
    def tokens(self) -> int:
        return (self.img // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.chans * self.patch * self.patch


@dataclass(frozen=True)
class CnnConfig:
    """Small conv denoiser (LDM / DDPM U-Net substitute)."""

    name: str
    img: int
    chans: int
    widths: Tuple[int, ...]
    kernel: int
    batch: int
    family: str = "cnn"
    control: bool = False  # ControlNet-style conditioning branch


@dataclass(frozen=True)
class SitConfig:
    """Transformer diffusion-ish model: patch tokens -> velocity field."""

    name: str
    d: int
    layers: int
    heads: int
    img: int
    patch: int
    chans: int
    batch: int
    family: str = "sit"

    @property
    def tokens(self) -> int:
        return (self.img // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.chans * self.patch * self.patch


@dataclass(frozen=True)
class LlavaConfig:
    """Multimodal stub: frozen 'CLIP' features + projector + LM trunk."""

    name: str
    feat: int           # vision feature dim
    d: int
    layers: int
    heads: int
    vocab: int          # question token vocab
    seq: int            # question length
    answers: int        # answer classes
    batch: int
    family: str = "llava"


MODELS: Dict[str, object] = {}


def _reg(cfg) -> None:
    MODELS[cfg.name] = cfg


_reg(LmConfig("lm_tiny", d=128, layers=2, heads=2, vocab=512, seq=64, batch=8))
_reg(LmConfig("lm_small", d=256, layers=4, heads=4, vocab=2048, seq=128, batch=8))
_reg(LmConfig("lm_base", d=512, layers=8, heads=8, vocab=4096, seq=128, batch=8))
_reg(LmConfig("lm_large", d=768, layers=12, heads=12, vocab=8192, seq=256, batch=4))
_reg(VitConfig("vit_tiny", d=128, layers=2, heads=2, img=16, patch=4, chans=3,
               classes=10, batch=32))
_reg(VitConfig("vit_small", d=192, layers=4, heads=3, img=32, patch=4, chans=3,
               classes=100, batch=32))
_reg(CnnConfig("cnn_tiny", img=16, chans=3, widths=(16, 32, 16), kernel=3, batch=16))
_reg(CnnConfig("cnn_small", img=32, chans=3, widths=(32, 64, 32), kernel=3, batch=16))
_reg(CnnConfig("cnn_celeb", img=64, chans=3, widths=(32, 64, 64, 32), kernel=3, batch=8))
_reg(SitConfig("sit_small", d=256, layers=4, heads=4, img=32, patch=4, chans=3, batch=16))
_reg(CnnConfig("ctrl_small", img=32, chans=3, widths=(32, 64, 32), kernel=3,
               batch=8, control=True))
_reg(LlavaConfig("llava_small", feat=512, d=256, layers=4, heads=4, vocab=1024,
                 seq=32, answers=16, batch=16))


# ---------------------------------------------------------------------------
# Param census per model (must match models/*.py param order exactly)
# ---------------------------------------------------------------------------

def lm_param_specs(cfg: LmConfig) -> List[ParamSpec]:
    s = []
    s.append(ParamSpec("embed", (cfg.vocab, cfg.d)))
    for i in range(cfg.layers):
        p = f"blk{i}."
        s.append(ParamSpec(p + "ln1", (cfg.d,), kind="vector", init="ones"))
        s.append(ParamSpec(p + "wq", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "wk", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "wv", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "wo", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "ln2", (cfg.d,), kind="vector", init="ones"))
        s.append(ParamSpec(p + "w1", (cfg.d, cfg.mlp)))
        s.append(ParamSpec(p + "w2", (cfg.mlp, cfg.d)))
    s.append(ParamSpec("lnf", (cfg.d,), kind="vector", init="ones"))
    s.append(ParamSpec("head", (cfg.d, cfg.vocab)))
    return s


def vit_param_specs(cfg: VitConfig) -> List[ParamSpec]:
    s = []
    s.append(ParamSpec("patch_embed", (cfg.patch_dim, cfg.d)))
    s.append(ParamSpec("pos_embed", (cfg.tokens, cfg.d), kind="vector", scale=0.02,
                       init="normal"))
    for i in range(cfg.layers):
        p = f"blk{i}."
        s.append(ParamSpec(p + "ln1", (cfg.d,), kind="vector", init="ones"))
        s.append(ParamSpec(p + "wq", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "wk", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "wv", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "wo", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "ln2", (cfg.d,), kind="vector", init="ones"))
        s.append(ParamSpec(p + "w1", (cfg.d, 4 * cfg.d)))
        s.append(ParamSpec(p + "w2", (4 * cfg.d, cfg.d)))
    s.append(ParamSpec("lnf", (cfg.d,), kind="vector", init="ones"))
    s.append(ParamSpec("head", (cfg.d, cfg.classes)))
    return s


def cnn_param_specs(cfg: CnnConfig) -> List[ParamSpec]:
    s = []
    k = cfg.kernel
    chain = (cfg.chans,) + cfg.widths
    for i in range(len(chain) - 1):
        s.append(ParamSpec(f"conv{i}.w", (chain[i + 1], chain[i], k, k), kind="conv",
                           scale=0.1))
        s.append(ParamSpec(f"conv{i}.b", (chain[i + 1],), kind="vector", init="zeros"))
    s.append(ParamSpec("conv_out.w", (cfg.chans, chain[-1], k, k), kind="conv",
                       scale=0.1))
    s.append(ParamSpec("conv_out.b", (cfg.chans,), kind="vector", init="zeros"))
    if cfg.control:
        # control branch: takes the 1-channel control map to mid-width features
        mid = cfg.widths[len(cfg.widths) // 2]
        s.append(ParamSpec("ctrl0.w", (cfg.widths[0], 1, k, k), kind="conv", scale=0.1))
        s.append(ParamSpec("ctrl0.b", (cfg.widths[0],), kind="vector", init="zeros"))
        s.append(ParamSpec("ctrl1.w", (mid, cfg.widths[0], k, k), kind="conv",
                           scale=0.1))
        s.append(ParamSpec("ctrl1.b", (mid,), kind="vector", init="zeros"))
    return s


def sit_param_specs(cfg: SitConfig) -> List[ParamSpec]:
    s = []
    s.append(ParamSpec("patch_embed", (cfg.patch_dim, cfg.d)))
    s.append(ParamSpec("pos_embed", (cfg.tokens, cfg.d), kind="vector"))
    s.append(ParamSpec("time_embed", (cfg.d,), kind="vector"))
    for i in range(cfg.layers):
        p = f"blk{i}."
        s.append(ParamSpec(p + "ln1", (cfg.d,), kind="vector", init="ones"))
        s.append(ParamSpec(p + "wq", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "wk", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "wv", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "wo", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "ln2", (cfg.d,), kind="vector", init="ones"))
        s.append(ParamSpec(p + "w1", (cfg.d, 4 * cfg.d)))
        s.append(ParamSpec(p + "w2", (4 * cfg.d, cfg.d)))
    s.append(ParamSpec("lnf", (cfg.d,), kind="vector", init="ones"))
    s.append(ParamSpec("head", (cfg.d, cfg.patch_dim)))
    return s


def llava_param_specs(cfg: LlavaConfig) -> List[ParamSpec]:
    s = []
    s.append(ParamSpec("projector", (cfg.feat, cfg.d)))
    s.append(ParamSpec("embed", (cfg.vocab, cfg.d)))
    for i in range(cfg.layers):
        p = f"blk{i}."
        s.append(ParamSpec(p + "ln1", (cfg.d,), kind="vector", init="ones"))
        s.append(ParamSpec(p + "wq", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "wk", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "wv", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "wo", (cfg.d, cfg.d)))
        s.append(ParamSpec(p + "ln2", (cfg.d,), kind="vector", init="ones"))
        s.append(ParamSpec(p + "w1", (cfg.d, 4 * cfg.d)))
        s.append(ParamSpec(p + "w2", (4 * cfg.d, cfg.d)))
    s.append(ParamSpec("lnf", (cfg.d,), kind="vector", init="ones"))
    s.append(ParamSpec("answer_head", (cfg.d, cfg.answers)))
    return s


def param_specs(cfg) -> List[ParamSpec]:
    return {
        "lm": lm_param_specs,
        "vit": vit_param_specs,
        "cnn": cnn_param_specs,
        "sit": sit_param_specs,
        "llava": llava_param_specs,
    }[cfg.family](cfg)


def param_count(cfg) -> int:
    return sum(p.numel for p in param_specs(cfg))


# ---------------------------------------------------------------------------
# Rank policy (paper's rank-ratio convention: r = min(m,n)/c)
# ---------------------------------------------------------------------------

def rank_for(shape: Tuple[int, ...], ratio: float) -> int:
    mn = min(shape[0], shape[1])
    return min(mn, max(4, int(mn / ratio)))


def conv_ranks(shape: Tuple[int, ...], ratio: float) -> Tuple[int, int]:
    """Tucker-2 ranks (r_O, r_I) for an OIHW conv weight, clamped to the
    mode dimensions (a 1-input-channel control conv gets r_I = 1)."""
    o, i = shape[0], shape[1]
    return min(o, max(2, int(o / ratio))), min(i, max(2, int(i / ratio)))


# ---------------------------------------------------------------------------
# Experiments: which (model, rank-ratio) combinations need artifacts
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Experiment:
    """One paper table/figure: which model and which rank ratios it sweeps."""

    id: str
    model: str
    ratios: Tuple[float, ...] = (4.0,)
    note: str = ""


EXPERIMENTS: List[Experiment] = [
    Experiment("table1_ldm", "cnn_tiny", (2.0,), "LDM pre-train substitute"),
    Experiment("table2_sit", "sit_small", (2.0,), "SiT-XL/2 + REPA substitute"),
    Experiment("table3_controlnet", "ctrl_small", (2.0, 4.0, 8.0),
               "ControlNet-SDXL rank-ratio sweep"),
    Experiment("table5_llama1b", "lm_small", (4.0,), "LLaMA-1B substitute"),
    Experiment("table5_llama7b", "lm_base", (4.0,), "LLaMA-7B substitute"),
    Experiment("table6_llava", "llava_small", (4.0,), "LLaVA fine-tune substitute"),
    Experiment("table7_ablation", "vit_tiny", (4.0,), "Eqn6/Eqn7 component ablation"),
    Experiment("fig3_ceu", "vit_tiny", (4.0,), "CEU trajectory comparison"),
    Experiment("fig4_grid", "vit_tiny", (2.0, 4.0, 8.0), "lambda/r/T_u grid"),
    Experiment("app_ddpm_cifar", "cnn_small", (1.5,), "DDPM CIFAR-10 substitute"),
    Experiment("app_ddpm_celeba", "cnn_celeb", (2.0,), "DDPM CelebA-HQ substitute"),
    Experiment("app_tucker", "cnn_tiny", (4.0,), "Tucker format comparison"),
    Experiment("e2e_lm", "lm_base", (4.0,), "end-to-end training driver"),
    Experiment("e2e_lm_large", "lm_large", (4.0,), "large config (opt-in)"),
    Experiment("smoke", "lm_tiny", (4.0,), "integration tests"),
]


def normalized_matrix_shape(shape: Tuple[int, ...]) -> Tuple[int, int]:
    """Projection-frame shape (m', n') with m' >= n' (GaLore side rule)."""
    m, n = shape
    return (m, n) if m >= n else (n, m)
