"""AOT driver: lower every graph the experiments need to HLO text.

Run once via `make artifacts` (from python/):

    python -m compile.aot --out ../artifacts [--only PREFIX] [--force]

Outputs:
    artifacts/<graph>.hlo.txt   one per (template, shape) instantiation
    artifacts/manifest.json     the Rust<->Python contract (DESIGN.md §2)

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
runtime behind the Rust `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Graph naming (mirrored by rust/src/runtime/names.rs):
    matrix proj:  {tpl}__{m}x{n}_r{r}
    full-rank:    {tpl}__{m}x{n}
    conv:         {tpl}__{o}x{i}x{k1}x{k2}_rO{ro}_rI{ri}[_rS{rs}]
    models:       train_step__{model}, eval_step__{model}
"""

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import optim, shapes
from .models import module_for
from .shapes import EXPERIMENTS, MODELS, conv_ranks, param_specs, rank_for

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dt(dtype) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(dtype).name]


class GraphDef:
    """A lowerable graph: fn + positional input ShapeDtypeStructs."""

    def __init__(self, name, fn, inputs, outputs, template, meta=None):
        self.name = name
        self.fn = fn
        self.inputs = inputs      # list of ShapeDtypeStruct (flat order)
        self.outputs = outputs    # list of (shape tuple, dtype)
        self.template = template
        self.meta = meta or {}

    def manifest_entry(self):
        flat = []
        for s in self.inputs:  # model graphs nest the params tuple first
            flat.extend(s) if isinstance(s, tuple) else flat.append(s)
        return {
            "file": self.name + ".hlo.txt",
            "template": self.template,
            "inputs": [{"shape": list(s.shape), "dtype": _dt(s.dtype)}
                       for s in flat],
            "outputs": [{"shape": list(s), "dtype": d} for s, d in self.outputs],
            **self.meta,
        }


# ---------------------------------------------------------------------------
# Matrix optimizer graph instantiation
# ---------------------------------------------------------------------------

def matrix_graphs(m, n, r):
    """All optimizer graphs for a raw weight shape (m, n) at rank r."""
    tr = m < n
    mb, nb = max(m, n), min(m, n)
    sc = sds(())
    w, g = sds((m, n)), sds((m, n))
    mom, vmom = sds((mb, r)), sds((mb, r))
    p = sds((nb, r))
    rfac, cfac = sds((mb, 1)), sds((1, r))
    defs = []

    defs.append(GraphDef(
        f"coap_adam_step__{m}x{n}_r{r}",
        functools.partial(optim.coap_adam_step, transpose=tr),
        [w, g, mom, vmom, p, sc, sc, sc, sc],
        [((m, n), "f32"), ((mb, r), "f32"), ((mb, r), "f32"), ((), "f32")],
        "coap_adam_step", {"rank": r}))

    defs.append(GraphDef(
        f"coap_adafactor_step__{m}x{n}_r{r}",
        functools.partial(optim.coap_adafactor_step, transpose=tr),
        [w, g, mom, rfac, cfac, p, sc, sc],
        [((m, n), "f32"), ((mb, r), "f32"), ((mb, 1), "f32"),
         ((1, r), "f32"), ((), "f32")],
        "coap_adafactor_step", {"rank": r}))

    defs.append(GraphDef(
        f"pupdate__{m}x{n}_r{r}",
        functools.partial(optim.pupdate, transpose=tr),
        [p, g, mom],
        [((nb, r), "f32")],
        "pupdate", {"rank": r}))

    defs.append(GraphDef(
        f"recalib__{m}x{n}_r{r}",
        functools.partial(optim.recalib, transpose=tr),
        [p, g],
        [((nb, r), "f32")],
        "recalib", {"rank": r}))

    defs.append(GraphDef(
        f"galore_svd__{m}x{n}_r{r}",
        functools.partial(optim.galore_svd, rank=r, transpose=tr),
        [g],
        [((nb, r), "f32")],
        "galore_svd", {"rank": r}))

    a, b = sds((r, n)), sds((m, r))
    defs.append(GraphDef(
        f"lora_adam_step__{m}x{n}_r{r}",
        optim.lora_adam_step,
        [w, a, b, g, a, a, b, b, sc, sc, sc],
        [((m, n), "f32"), ((r, n), "f32"), ((m, r), "f32"),
         ((r, n), "f32"), ((r, n), "f32"), ((m, r), "f32"), ((m, r), "f32"),
         ((), "f32")],
        "lora_adam_step", {"rank": r}))
    return defs


def fullrank_graphs(m, n):
    sc = sds(())
    w, g = sds((m, n)), sds((m, n))
    defs = [
        GraphDef(f"adam_step__{m}x{n}", optim.adam_step,
                 [w, g, w, w, sc, sc, sc, sc],
                 [((m, n), "f32")] * 3 + [((), "f32")],
                 "adam_step"),
        GraphDef(f"adafactor_step__{m}x{n}", optim.adafactor_step,
                 [w, g, w, sds((m, 1)), sds((1, n)), sc, sc],
                 [((m, n), "f32"), ((m, n), "f32"), ((m, 1), "f32"),
                  ((1, n), "f32"), ((), "f32")],
                 "adafactor_step"),
    ]
    return defs


def conv_graphs(o, i, k1, k2, ro, ri, with_full=False):
    sc = sds(())
    w = sds((o, i, k1, k2))
    mom = sds((ro, ri, k1, k2))
    po, pi = sds((o, ro)), sds((i, ri))
    base = f"{o}x{i}x{k1}x{k2}_rO{ro}_rI{ri}"
    defs = []

    defs.append(GraphDef(
        f"coap_adam_conv_step__{base}", optim.coap_adam_conv_step,
        [w, w, mom, mom, po, pi, sc, sc, sc, sc],
        [((o, i, k1, k2), "f32"), ((ro, ri, k1, k2), "f32"),
         ((ro, ri, k1, k2), "f32"), ((), "f32")],
        "coap_adam_conv_step", {"rank_o": ro, "rank_i": ri}))

    defs.append(GraphDef(
        f"coap_adafactor_conv_step__{base}", optim.coap_adafactor_conv_step,
        [w, w, mom, sds((ro, 1)), sds((1, ri * k1 * k2)), po, pi, sc, sc],
        [((o, i, k1, k2), "f32"), ((ro, ri, k1, k2), "f32"),
         ((ro, 1), "f32"), ((1, ri * k1 * k2), "f32"), ((), "f32")],
        "coap_adafactor_conv_step", {"rank_o": ro, "rank_i": ri}))

    for mode, p, r, side in ((1, po, ro, "o"), (2, pi, ri, "i")):
        other = pi if mode == 1 else po
        defs.append(GraphDef(
            f"conv_pupdate_{side}__{base}",
            functools.partial(optim.conv_pupdate, mode=mode),
            [p, w, mom, other],
            [((o, ro) if mode == 1 else (i, ri), "f32")],
            f"conv_pupdate_{side}", {"rank_o": ro, "rank_i": ri}))
        defs.append(GraphDef(
            f"conv_recalib_{side}__{base}",
            functools.partial(optim.conv_recalib, mode=mode),
            [p, w],
            [((o, ro) if mode == 1 else (i, ri), "f32")],
            f"conv_recalib_{side}", {"rank_o": ro, "rank_i": ri}))
        defs.append(GraphDef(
            f"conv_svd_{side}__{base}",
            functools.partial(optim.conv_svd, rank=r, mode=mode),
            [w],
            [((o, ro) if mode == 1 else (i, ri), "f32")],
            f"conv_svd_{side}", {"rank_o": ro, "rank_i": ri}))

    if with_full:
        rs = max(2, (k1 * k2) // 2)
        ps = sds((k1 * k2, rs))
        mom3 = sds((ro, ri, rs))
        defs.append(GraphDef(
            f"coap_adam_convfull_step__{base}_rS{rs}",
            optim.coap_adam_convfull_step,
            [w, w, mom3, mom3, po, pi, ps, sc, sc, sc, sc],
            [((o, i, k1, k2), "f32"), ((ro, ri, rs), "f32"),
             ((ro, ri, rs), "f32"), ((), "f32")],
            "coap_adam_convfull_step",
            {"rank_o": ro, "rank_i": ri, "rank_s": rs}))
    return defs


# ---------------------------------------------------------------------------
# Model graphs
# ---------------------------------------------------------------------------

def model_graphs(cfg):
    mod = module_for(cfg)
    specs = param_specs(cfg)
    p_sds = tuple(sds(p.shape) for p in specs)
    d_sds = [sds(s, dt) for _, s, dt in mod.data_specs(cfg)]

    def train_step(params, *data):
        loss, grads = jax.value_and_grad(
            lambda ps: mod.loss_fn(ps, *data, cfg=cfg))(params)
        return (loss, *grads)

    train_out = [((), "f32")] + [(p.shape, "f32") for p in specs]
    defs = [GraphDef(f"train_step__{cfg.name}", train_step,
                     [p_sds, *d_sds],
                     train_out, "train_step", {"model": cfg.name})]

    if hasattr(mod, "eval_fn"):
        def eval_step(params, *data):
            return mod.eval_fn(params, *data, cfg=cfg)
        if cfg.family == "cnn":
            ev_out = [((), "f32"),
                      (tuple(d_sds[0].shape), "f32")]  # loss, pred
        else:
            ev_out = [((), "f32"), ((), "f32")]        # loss, n_correct
    else:
        def eval_step(params, *data):
            return (mod.loss_fn(params, *data, cfg=cfg),)
        ev_out = [((), "f32")]
    defs.append(GraphDef(f"eval_step__{cfg.name}", eval_step,
                         [p_sds, *d_sds], ev_out, "eval_step",
                         {"model": cfg.name}))
    return defs


# ---------------------------------------------------------------------------
# Registry assembly
# ---------------------------------------------------------------------------

def build_registry():
    """Dedup-by-name union of every graph any experiment needs."""
    reg = {}

    def add(defs):
        for d in defs:
            reg.setdefault(d.name, d)

    needed_models = set()
    for exp in EXPERIMENTS:
        cfg = MODELS[exp.model]
        needed_models.add(cfg.name)
        with_full = exp.id == "app_tucker"
        for p in param_specs(cfg):
            if p.kind == "matrix":
                m, n = p.shape
                add(fullrank_graphs(m, n))
                for ratio in exp.ratios:
                    r = rank_for(p.shape, ratio)
                    add(matrix_graphs(m, n, r))
            elif p.kind == "conv":
                o, i, k1, k2 = p.shape
                add(fullrank_graphs(o, i * k1 * k2))
                for ratio in exp.ratios:
                    ro, ri = conv_ranks(p.shape, ratio)
                    add(conv_graphs(o, i, k1, k2, ro, ri, with_full=with_full))
                    # Tucker-1 path reuses matrix graphs on the mode-1
                    # unfolding (DESIGN.md §3): (O, I*K1*K2) at rank rO.
                    if with_full:
                        add(matrix_graphs(o, i * k1 * k2, ro))

    for name in sorted(needed_models):
        add(model_graphs(MODELS[name]))
    return reg


def model_manifest(cfg):
    mod = module_for(cfg)
    specs = param_specs(cfg)
    entry = {
        "family": cfg.family,
        "cfg": {k: (list(v) if isinstance(v, tuple) else v)
                for k, v in cfg.__dict__.items()},
        "param_count": sum(p.numel for p in specs),
        "params": [{"name": p.name, "shape": list(p.shape), "kind": p.kind,
                    "init": p.init, "scale": p.scale} for p in specs],
        "data": [{"name": nm, "shape": list(s), "dtype": _dt(dt)}
                 for nm, s, dt in mod.data_specs(cfg)],
        "train_step": f"train_step__{cfg.name}",
        "eval_step": f"eval_step__{cfg.name}",
        "eval_outputs": mod.eval_outputs(cfg),
    }
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower only names with prefix")
    ap.add_argument("--force", action="store_true", help="relower existing files")
    ap.add_argument("--list", action="store_true", help="print names and exit")
    args = ap.parse_args()

    reg = build_registry()
    if args.list:
        for name in sorted(reg):
            print(name)
        print(f"total: {len(reg)} graphs", file=sys.stderr)
        return

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    lowered_n = skipped = 0
    for idx, name in enumerate(sorted(reg)):
        if args.only and not name.startswith(args.only):
            continue
        gd = reg[name]
        path = os.path.join(args.out, gd.name + ".hlo.txt")
        if os.path.exists(path) and not args.force:
            skipped += 1
            continue
        lowered = jax.jit(gd.fn).lower(*gd.inputs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        lowered_n += 1
        if lowered_n % 25 == 0:
            print(f"[{idx + 1}/{len(reg)}] {name} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    manifest = {
        "version": 1,
        "graphs": {n: reg[n].manifest_entry() for n in sorted(reg)},
        "models": {m: model_manifest(MODELS[m])
                   for m in sorted({e.model for e in EXPERIMENTS})},
        "experiments": [{"id": e.id, "model": e.model,
                         "ratios": list(e.ratios), "note": e.note}
                        for e in EXPERIMENTS],
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"artifacts: {lowered_n} lowered, {skipped} cached, "
          f"{len(reg)} total in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
