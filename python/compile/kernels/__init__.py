"""L1 Pallas kernels (build-time only; lowered into the L2 HLO graphs).

COAP_PALLAS_SCOPE selects which call sites lower through Pallas
(correctness is identical either way — pytest asserts kernel == oracle):

  all   every kernel through Pallas. The TPU-structure configuration
        (tiles sized for VMEM / the MXU; DESIGN.md §Hardware-Adaptation).
  proj  (default) the Eqn-6 CosSim-gradient kernel — the paper's novel
        compute, executed every T_u steps — stays Pallas; the per-step
        adam-update/matmul go through the jnp oracles. This is the CPU
        hardware adaptation: interpret-mode grids cost ~5.8x wallclock on
        CPU (EXPERIMENTS.md §Perf), and the per-step path runs every
        layer every step.
  none  all oracles (debug / lowering-cost comparisons).

COAP_DISABLE_PALLAS=1 is a back-compat alias for scope=none.
"""

import os

from . import ref

_SCOPE = os.environ.get("COAP_PALLAS_SCOPE", "proj")
if os.environ.get("COAP_DISABLE_PALLAS", "0") == "1":
    _SCOPE = "none"

if _SCOPE == "all":
    from .projected_update import adam_update
    from .projection_matmul import matmul
    from .pupdate import cosgrad_rows
elif _SCOPE == "proj":
    adam_update = ref.adam_update_ref
    matmul = ref.matmul_ref
    from .pupdate import cosgrad_rows
elif _SCOPE == "none":
    adam_update = ref.adam_update_ref
    matmul = ref.matmul_ref
    cosgrad_rows = ref.cosgrad_rows_ref
else:
    raise ValueError(f"COAP_PALLAS_SCOPE={_SCOPE!r} (want all|proj|none)")

adafactor_update = ref.adafactor_update_ref  # row/col reductions: left to XLA

__all__ = ["adam_update", "matmul", "cosgrad_rows", "adafactor_update", "ref"]
