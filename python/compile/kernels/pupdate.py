"""L1 Pallas kernel: row-wise pieces of the Eqn-6 direction-term gradient.

For the inter-projection correlation-aware update, the CosSim gradient
(appendix Eqn. 6) needs, per gradient row i:

    A_i = G_i/(|Mhat_i||G_i|) - Mhat_i <Mhat_i,G_i>/(|Mhat_i|^3 |G_i|)

plus the per-row cosine for the objective value. Rows are independent, so
the kernel tiles over rows with the full row width N resident: one
HBM->VMEM pass produces both reductions (dot, two norms) and the A tile.

TPU mapping: block (bm, N) with bm chosen so 2*bm*N*4 bytes (mhat+g tiles)
plus the A output tile fit VMEM — bm=128 covers N up to ~8k. All VPU work;
the reductions are lane-wise adds feeding a scalar broadcast.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import COS_EPS

DEFAULT_BM = 128


def _kernel(mhat_ref, g_ref, a_ref, cos_ref, *, eps):
    mhat = mhat_ref[...]
    g = g_ref[...]
    d = jnp.sum(mhat * g, axis=1, keepdims=True)
    nm = jnp.sqrt(jnp.sum(mhat * mhat, axis=1, keepdims=True))
    ng = jnp.sqrt(jnp.sum(g * g, axis=1, keepdims=True))
    denom = nm * ng + eps
    a_ref[...] = g / denom - mhat * d / (nm * nm * denom + eps)
    cos_ref[...] = d / denom


def cosgrad_rows(mhat, g, eps=COS_EPS, bm=DEFAULT_BM):
    """Same contract as ref.cosgrad_rows_ref: returns (A, cos_rows)."""
    assert mhat.shape == g.shape and mhat.ndim == 2
    m, n = mhat.shape
    bm = min(bm, m)
    pm = (-m) % bm
    # Rows are independent; zero-padded rows produce garbage A rows that we
    # slice away (their norms are eps, no NaNs thanks to the +eps guards).
    mp = jnp.pad(mhat, ((0, pm), (0, 0))) if pm else mhat
    gp = jnp.pad(g, ((0, pm), (0, 0))) if pm else g
    grid = ((m + pm) // bm,)

    a, cos_rows = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m + pm, n), jnp.float32),
            jax.ShapeDtypeStruct((m + pm, 1), jnp.float32),
        ],
        interpret=True,
    )(mp, gp)
    return a[:m], cos_rows[:m]
