"""L1 Pallas kernel: fused (projected-)Adam moment update.

One kernel fuses the first-moment EMA, the second-moment EMA, both bias
corrections, and the rsqrt step direction, so each (M, V, G) tile makes a
single HBM->VMEM round trip per optimizer step.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles (M, R) into
(bm, br) VMEM blocks; bm=256, br<=256 keeps the five live f32 operands
under ~1.3 MB — comfortably inside a TensorCore's 16 MB VMEM with double
buffering. Everything is element-wise (VPU work, no MXU), so the roofline
is HBM bandwidth; fusing the three passes of a naive implementation into
one is the entire optimization.

CPU note: lowered with interpret=True (Mosaic custom-calls cannot run on
the CPU PJRT plugin); the grid loop becomes an XLA loop over slices.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ADAM_EPS

DEFAULT_BM = 256
DEFAULT_BR = 256


def _kernel(b1t_ref, b2t_ref, m_ref, v_ref, g_ref, mo_ref, vo_ref, do_ref,
            *, beta1, beta2, eps):
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * (g * g)
    m_hat = m / (1.0 - b1t_ref[0, 0])
    v_hat = v / (1.0 - b2t_ref[0, 0])
    mo_ref[...] = m
    vo_ref[...] = v
    do_ref[...] = m_hat / (jnp.sqrt(v_hat) + eps)


def _pad_to(x, bm, bn):
    m, n = x.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def adam_update(m, v, g, b1t, b2t, beta1=0.9, beta2=0.999, eps=ADAM_EPS,
                bm=DEFAULT_BM, br=DEFAULT_BR):
    """Fused Adam moment update. Same contract as ref.adam_update_ref.

    m, v, g: (M, R) f32. b1t/b2t: scalars (python float or 0-d array).
    Returns (m_new, v_new, delta).
    """
    assert m.shape == v.shape == g.shape and m.ndim == 2
    mm, rr = m.shape
    bm = min(bm, mm)
    br = min(br, rr)
    mp = _pad_to(m, bm, br)
    vp = _pad_to(v, bm, br)
    gp = _pad_to(g, bm, br)
    pm, pr = mp.shape
    grid = (pm // bm, pr // br)
    b1t_arr = jnp.full((1, 1), b1t, dtype=m.dtype)
    b2t_arr = jnp.full((1, 1), b2t, dtype=m.dtype)

    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    tile_spec = pl.BlockSpec((bm, br), lambda i, j: (i, j))
    out_shape = [jax.ShapeDtypeStruct((pm, pr), m.dtype)] * 3

    mo, vo, do = pl.pallas_call(
        functools.partial(_kernel, beta1=beta1, beta2=beta2, eps=eps),
        grid=grid,
        in_specs=[scalar_spec, scalar_spec, tile_spec, tile_spec, tile_spec],
        out_specs=[tile_spec, tile_spec, tile_spec],
        out_shape=out_shape,
        interpret=True,
    )(b1t_arr, b2t_arr, mp, vp, gp)
    return mo[:mm, :rr], vo[:mm, :rr], do[:mm, :rr]
