"""L1 Pallas kernel: tiled projection matmul (G @ P and friends).

TPU mapping (DESIGN.md §Hardware-Adaptation): grid tiles the output (M, R)
into MXU-aligned (bm, br) blocks (multiples of 128 feed the 128x128
systolic array); the contraction dimension K is kept whole per tile —
for COAP's projections K = n <= 4096, so an f32 (128, K) A-slab plus a
(K, 128) B-slab stay under 4 MB of VMEM, and `jnp.dot` inside the kernel
maps to one MXU pass with f32 accumulation (`preferred_element_type`).

This is the paper's hot matmul family: G@P (project), Delta@P^T (restore),
and the G^T G P products inside the Eqn-6 update.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...],
                         preferred_element_type=jnp.float32)


def matmul(a, b, bm=DEFAULT_BM, bn=DEFAULT_BN):
    """a (M, K) @ b (K, N) -> (M, N), f32 accumulation."""
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0]
    m, k = a.shape
    _, n = b.shape
    bm = min(bm, m)
    bn = min(bn, n)
    pm = (-m) % bm
    pn = (-n) % bn
    ap = jnp.pad(a, ((0, pm), (0, 0))) if pm else a
    bp = jnp.pad(b, ((0, 0), (0, pn))) if pn else b
    gm, gn = (m + pm) // bm, (n + pn) // bn

    out = pl.pallas_call(
        _kernel,
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]
