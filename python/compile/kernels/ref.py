"""Pure-jnp oracles for every Pallas kernel.

These define the exact semantics a kernel must reproduce; the pytest suite
(`python/tests/test_kernels.py`) asserts allclose between each kernel and
its oracle over hypothesis-generated shapes. They are also imported by
`optim.py` when COAP_DISABLE_PALLAS=1 (debug / perf-comparison path).
"""

import jax.numpy as jnp

ADAM_EPS = 1e-8
# f32-safe: denominators are formed as (nm*ng + eps) and (nm^2*denom +
# eps) so exactly-zero rows (unseen embedding tokens) yield 0, not 0/0.
# 1e-12 would underflow when cubed in f32.
COS_EPS = 1e-8


def adam_update_ref(m, v, g, b1t, b2t, beta1=0.9, beta2=0.999, eps=ADAM_EPS):
    """Fused Adam moment update + bias-corrected step direction.

    Args:
      m, v, g: (M, R) first moment, second moment, (projected) gradient.
      b1t, b2t: scalars beta1**t, beta2**t (bias-correction powers).
    Returns:
      (m_new, v_new, delta) with delta = m_hat / (sqrt(v_hat) + eps).
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * (g * g)
    m_hat = m_new / (1.0 - b1t)
    v_hat = v_new / (1.0 - b2t)
    delta = m_hat / (jnp.sqrt(v_hat) + eps)
    return m_new, v_new, delta


def matmul_ref(a, b):
    """Plain a @ b in f32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def cosgrad_rows_ref(mhat, g, eps=COS_EPS):
    """Row-wise pieces of the Eqn-6 direction-term gradient.

    For each row i:
      d_i   = <mhat_i, g_i>
      nm_i  = ||mhat_i||,  ng_i = ||g_i||,  den_i = nm_i*ng_i + eps
      A_i   = g_i / den_i - mhat_i * d_i / (nm_i^2 * den_i + eps)
      cos_i = d_i / den_i
    Returns (A, cos_rows) with A (M, N) and cos_rows (M, 1).
    CosSim(mhat, g) = mean(cos_rows); dCos/dP = (1/m) A^T @ M_proj.
    """
    d = jnp.sum(mhat * g, axis=1, keepdims=True)
    nm = jnp.sqrt(jnp.sum(mhat * mhat, axis=1, keepdims=True))
    ng = jnp.sqrt(jnp.sum(g * g, axis=1, keepdims=True))
    denom = nm * ng + eps
    a = g / denom - mhat * d / (nm * nm * denom + eps)
    cos_rows = d / denom
    return a, cos_rows


def adafactor_update_ref(m, r, c, g, t, beta1=0.9, eps=1e-30, decay=-0.8):
    """Adafactor second-moment factored update with first-moment momentum.

    Implements the paper's Algorithm 2 body (projected frame):
      beta2_t = 1 - t**decay
      R = beta2_t R + (1-beta2_t) sum(G^2, axis=1)   (rows, (M,1))
      C = beta2_t C + (1-beta2_t) sum(G^2, axis=0)   (cols, (1,N))
      Vhat = sqrt(mean(R) / (R C))    (element-wise rsqrt scale)
      M = beta1 M + (1-beta1) G
      delta = M * Vhat
    Returns (m_new, r_new, c_new, delta).
    """
    beta2t = 1.0 - jnp.power(t, decay)
    g2 = g * g + eps
    r_new = beta2t * r + (1.0 - beta2t) * jnp.sum(g2, axis=1, keepdims=True)
    c_new = beta2t * c + (1.0 - beta2t) * jnp.sum(g2, axis=0, keepdims=True)
    vhat = jnp.sqrt(jnp.mean(r_new) / (r_new * c_new + eps))
    m_new = beta1 * m + (1.0 - beta1) * g
    delta = m_new * vhat
    return m_new, r_new, c_new, delta
