import os
import sys

# Allow `pytest python/tests` from the repo root: tests import the
# `compile` package that lives next to this file.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
